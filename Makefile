# Reproduce the tier-1 green state with one command.
.PHONY: test test-fast bench-serve docs-check

# full suite (the roadmap's tier-1 command)
test:
	./scripts/ci.sh

# fast path: skip the slow multi-device subprocess tests
test-fast:
	FAST=1 ./scripts/ci.sh

# dead-link / missing-file check over *.md and module docstrings
docs-check:
	python scripts/check_docs.py

# continuous-batching throughput benchmark (CPU reduced config)
bench-serve:
	PYTHONPATH=src python benchmarks/serve_throughput.py
