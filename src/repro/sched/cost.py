"""Compute-aware per-slot decode-step cost model (paper §IV-B).

``sched/balance.py`` scores *residency* (page counts) — good enough at
admission time, but a slot mix that was page-balanced when admitted goes
lopsided as slots retire and contexts grow: streaming heads saturate at
``sink + local`` while retrieval heads keep growing with the selected
budget and the page-metadata scan, and a prefilling slot does chunk-sized
writes that no settled-page count sees. This module scores the *compute*
each slot will demand on its next engine step:

  decode slot    — streaming + retrieval head mix via ``slot_head_load``
                   at the speculative-verify horizon (``ctx + k - 1``: a
                   verify step appends up to k tokens before the host can
                   rebalance), with the striped-page read share capped at
                   the tiered hot set (``hot_cap``).
  prefill slot   — the chunk grant it will receive next step (computed
                   jointly across all prefilling slots via
                   ``chunk_allocation``, so backlog contention is scored,
                   not per-slot optimism) plus the settled-prefix gather
                   the chunk attends over.
  ready slot     — prompt fully fed, joins decode at the next phase
                   boundary: scored as a decode slot at its fed length.

Per-device aggregation goes through ``LayoutPlan.page_stripe_shards`` so
every registry layout inherits the model: the retrieval-heads' paged read
share stripes round-robin with the pages (coplace_shmap), while the
non-paged share pins to the slot's batch-axis bank.  Consumed by
``sched/rebalance.py`` and the engine's balance report.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import H2ealConfig
from repro.sched.balance import (
    chunk_allocation,
    slot_head_load,
    slot_pages,
)


@dataclass(frozen=True)
class SlotView:
    """Engine-side snapshot of one live slot (host mirrors only — building
    a view never touches device state)."""

    slot: int
    uid: int
    ctx: int            # tokens currently in the slot's cache
    prompt_left: int    # prompt tokens not yet fed (prefilling slots)
    phase: str          # "decode" | "prefill" | "ready"


@dataclass(frozen=True)
class SlotCost:
    """Scored per-step compute of one slot.

    ``compute`` is the total score (tokens of KV touched per step across
    all heads); ``paged_compute`` is the share attributable to striped
    page reads (moves with the pages under interleaved layouts, NOT with
    the slot index); ``pages`` is the device-resident page count backing
    that share (hot-capped under tiering)."""

    slot: int
    uid: int
    phase: str
    compute: float
    paged_compute: float
    pages: int


@dataclass(frozen=True)
class CostModel:
    """Frozen per-engine scoring parameters (head mix + serving mode)."""

    h2: H2ealConfig
    n_retrieval: int
    n_streaming: int
    hot_cap: Optional[int] = None
    spec_tokens: int = 0
    chunk_budget: int = 0

    @classmethod
    def from_config(cls, cfg, *, hot_cap: Optional[int] = None,
                    spec_tokens: int = 0,
                    chunk_budget: int = 0) -> "CostModel":
        """Head mix from the arch config: ``static_sparsity`` is the
        fraction of KV heads that are streaming (paper §IV-A)."""
        n_kv = int(cfg.num_kv_heads)
        nr = max(n_kv - round(n_kv * cfg.h2eal.static_sparsity), 0)
        return cls(h2=cfg.h2eal, n_retrieval=nr, n_streaming=n_kv - nr,
                   hot_cap=hot_cap, spec_tokens=int(spec_tokens),
                   chunk_budget=int(chunk_budget))

    # -- per-slot scores ----------------------------------------------------

    def _scored_pages(self, ctx: int) -> int:
        pages = slot_pages(ctx, self.h2.page_size)
        if self.hot_cap is not None:
            pages = min(pages, int(self.hot_cap))
        return pages

    def decode_cost(self, ctx: int) -> Tuple[float, float, int]:
        """(compute, paged_compute, pages) of one decode step at context
        ``ctx``, scored at the speculative-verify horizon."""
        horizon = max(int(self.spec_tokens) - 1, 0)
        c = int(ctx) + horizon
        stream = self.n_streaming * slot_head_load("streaming", self.h2, c)
        retr = self.n_retrieval * slot_head_load("retrieval", self.h2, c)
        # Streaming windows are per-slot ring buffers (never striped);
        # only the retrieval reads walk the interleaved pages.
        return stream + retr, retr, self._scored_pages(c)

    def prefill_cost(self, done: int, grant: int) -> Tuple[float, float, int]:
        """(compute, paged_compute, pages) of feeding ``grant`` chunk
        tokens onto ``done`` settled tokens: the chunk write itself plus
        the settled-prefix gather every chunk token attends over."""
        heads = self.n_streaming + self.n_retrieval
        gather = self.n_retrieval * slot_head_load("retrieval", self.h2,
                                                   int(done))
        return float(heads * int(grant)) + gather, gather, \
            self._scored_pages(int(done))

    def slot_costs(self, views: Sequence[SlotView], *,
                   n_shards: int = 1) -> List[SlotCost]:
        """Score every live slot. Prefill grants are allocated jointly
        (one shared ``chunk_budget`` per engine step, page-granular,
        device-aware — see ``chunk_allocation``); ``n_shards`` is the
        page striping factor the grants are placed against."""
        pre = [v for v in views if v.phase == "prefill"]
        grants = {}
        if pre:
            budget = self.chunk_budget if self.chunk_budget > 0 else \
                sum(v.prompt_left for v in pre)
            alloc = chunk_allocation([v.ctx for v in pre],
                                     [v.prompt_left for v in pre],
                                     budget, n_shards=max(int(n_shards), 1),
                                     page_size=self.h2.page_size)
            grants = {v.slot: g for v, g in zip(pre, alloc)}
        out: List[SlotCost] = []
        for v in views:
            if v.phase == "prefill":
                c, p, pg = self.prefill_cost(v.ctx, grants.get(v.slot, 0))
            else:  # decode / ready
                c, p, pg = self.decode_cost(v.ctx)
            out.append(SlotCost(slot=v.slot, uid=v.uid, phase=v.phase,
                                compute=c, paged_compute=p, pages=pg))
        return out


def slot_bank(slot: int, *, n_banks: int, max_batch: int) -> int:
    """Bank owning slot index ``slot`` under contiguous batch-axis
    blocking (the view GSPMD takes of a batch-sharded cache: bank j owns
    slots [j*B/n, (j+1)*B/n))."""
    assert 0 <= slot < max_batch
    return slot * n_banks // max_batch


def device_compute_loads(costs: Sequence[SlotCost], *, n_banks: int,
                         max_batch: int,
                         page_stripe_shards: int = 1) -> List[float]:
    """Aggregate slot costs into per-bank compute loads.

    The non-paged share of each slot pins to the bank owning its slot
    index (``slot_bank``).  When the layout stripes pages
    (``page_stripe_shards > 1``) the paged share is split proportional to
    each device's resident-page count under round-robin striping (floor
    share + one remainder page on the low-indexed devices, exactly as
    ``device_page_loads`` counts them), folded onto banks modulo
    ``n_banks`` — striped reads follow the *pages*, not the slot index,
    so migration moves only the pinned share."""
    loads = [0.0] * max(int(n_banks), 1)
    n_banks = len(loads)
    stripes = max(int(page_stripe_shards), 1)
    for c in costs:
        bank = slot_bank(c.slot, n_banks=n_banks, max_batch=max_batch)
        loads[bank] += c.compute - c.paged_compute
        if stripes > 1 and c.pages > 0:
            q, r = divmod(c.pages, stripes)
            per = [q + (1 if d < r else 0) for d in range(stripes)]
            total = sum(per)
            for d, p in enumerate(per):
                if p:
                    loads[d % n_banks] += c.paged_compute * p / total
        else:
            loads[bank] += c.paged_compute
    return loads
