"""Fused decode-window budgets (PR 10).

Between two page-selection boundaries the engine can run every reuse
step as ONE dispatched ``lax.scan`` (docs/serving.md §Fused decode
windows).  The scheduler's job is to tell that scan, per slot, how many
tokens it may emit before the device-side retirement mask flips — the
host learns of retirements only at the window boundary, so the budget
vector must encode every stop condition the per-step loop would have
checked on the host:

* the request's remaining token budget (``max_new`` countdown),
* the cache capacity ceiling (``lengths`` < capacity),
* the selection boundary itself (no slot may cross ``phase % w == 0``
  inside the window — selection refresh is a separate compiled step).

Pure NumPy on the host mirrors; nothing here touches device state.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def window_budgets(active: np.ndarray, remaining: np.ndarray,
                   lengths: np.ndarray, *, capacity: int,
                   phase_residue: int, share_window: int,
                   window: int) -> Tuple[int, np.ndarray]:
    """Per-slot emission budgets for one fused decode window.

    active/remaining/lengths: the engine's (B,) host mirrors. The window
    starts with every active slot at the same share-window residue
    ``phase_residue`` (the READY phase aligns admissions, so this is an
    invariant, not a request — serving/engine.py asserts it).

    Returns ``(n_useful, budgets)``: the number of scan iterations that
    can do useful work (== the budget of every slot that survives the
    whole window, so survivors stay phase-aligned at the next boundary)
    and the (B,) int32 budget vector — ≥ 1 for every active slot, 0
    elsewhere. A slot whose budget b < n_useful retires in-scan after
    emitting exactly b tokens.
    """
    if not 1 <= phase_residue < share_window:
        raise ValueError(
            f"fused window must start strictly inside a share window: "
            f"residue {phase_residue} vs share_window {share_window}")
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    n_useful = min(int(window), int(share_window) - int(phase_residue))
    budgets = np.zeros(active.shape[0], np.int32)
    for i in np.nonzero(active)[0]:
        b = min(n_useful, int(remaining[i]), int(capacity) - int(lengths[i]))
        if b < 1:
            raise ValueError(
                f"active slot {i} has no token budget (remaining="
                f"{remaining[i]}, lengths={lengths[i]}, capacity="
                f"{capacity}); it should have retired at the boundary")
        budgets[i] = b
    return n_useful, budgets
