"""Adaptive heterogeneous mapping: n_h KV heads onto n_b banks (paper §IV-C.1).

Cases:
  (a) n_b divisible by n_h — one stage; each head gets n_b/n_h banks
      (tensor parallelism within the group).
  (b) n_h > n_b — heads split into ceil(n_h/n_b) disjoint subsets executed
      as a sequential pipeline; each subset reduces to (a)/(c).
  (c) n_h < n_b, not divisible — greedy decomposition of n_h into distinct
      divisors of n_b (largest first); each part is a stage of case (a).

The paper's greedy can be infeasible (e.g. n_h=5, n_b=9: distinct divisors
{1,3} sum to at most 4) — we fall back to a final stage where the remaining
heads r get floor(n_b/r) banks each with n_b mod r banks idle, and report
the idle count.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class Stage:
    """One pipeline stage: ``heads`` executed with ``banks_per_head`` banks
    each (idle_banks banks unused)."""

    heads: tuple  # head ids in this stage
    banks_per_head: int
    idle_banks: int = 0


@dataclass(frozen=True)
class MappingPlan:
    n_heads: int
    n_banks: int
    stages: tuple  # tuple[Stage]

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def total_idle(self) -> int:
        return sum(s.idle_banks for s in self.stages)

    def validate(self) -> None:
        seen = []
        for s in self.stages:
            used = len(s.heads) * s.banks_per_head + s.idle_banks
            assert used == self.n_banks, (
                f"stage uses {used} banks != {self.n_banks}")
            seen.extend(s.heads)
        assert sorted(seen) == list(range(self.n_heads)), (
            "heads not partitioned exactly once")


def _divisors(n: int) -> List[int]:
    return sorted((d for d in range(1, n + 1) if n % d == 0), reverse=True)


def _greedy_distinct_divisors(n_h: int, n_b: int) -> List[int] | None:
    """Greedy largest-first decomposition of n_h into distinct divisors of
    n_b; None if infeasible."""
    parts: List[int] = []
    rest = n_h
    for d in _divisors(n_b):
        if d <= rest and d not in parts:
            parts.append(d)
            rest -= d
        if rest == 0:
            return parts
    return None


@dataclass(frozen=True)
class SlotAssignment:
    """Whole-slot → bank placement for a ragged batch (used when pages are
    NOT interleaved, so a slot's KV pins to one bank and the per-bank load
    is the sum of its slots' loads)."""

    n_banks: int
    banks: tuple     # tuple[tuple[int, ...]] — slot ids per bank
    loads: tuple     # per-bank total load

    @property
    def imbalance(self) -> float:
        from repro.sched.balance import load_imbalance
        return load_imbalance(self.loads)


def map_slots(slot_loads, n_banks: int) -> SlotAssignment:
    """Greedy LPT: place the heaviest slot on the least-loaded bank.

    The ragged-batch analogue of `map_heads` — the paper balances a fixed
    head population across banks (§IV-C.1); a continuous-batching batch
    additionally has per-SLOT load raggedness (each slot sits at its own
    context length). LPT is the standard 4/3-approximation for makespan
    and is what the engine's balance report scores non-interleaved
    placements with; under interleaved striping the split is exact and
    this mapping is unnecessary (see sched/balance.py).
    """
    assert n_banks >= 1
    order = sorted(range(len(slot_loads)), key=lambda i: -slot_loads[i])
    banks: List[List[int]] = [[] for _ in range(n_banks)]
    loads = [0.0] * n_banks
    for i in order:
        b = min(range(n_banks), key=lambda j: loads[j])
        banks[b].append(i)
        loads[b] += float(slot_loads[i])
    return SlotAssignment(n_banks=n_banks,
                          banks=tuple(tuple(b) for b in banks),
                          loads=tuple(loads))


def map_heads(n_h: int, n_b: int) -> MappingPlan:
    """Compute the stage plan mapping n_h KV heads onto n_b banks."""
    assert n_h >= 1 and n_b >= 1
    stages: List[Stage] = []
    head0 = 0

    def emit_subset(count: int) -> None:
        """Map `count` heads (<= n_b) onto all n_b banks."""
        nonlocal head0
        if n_b % count == 0:  # case (a)
            stages.append(Stage(
                heads=tuple(range(head0, head0 + count)),
                banks_per_head=n_b // count))
            head0 += count
            return
        parts = _greedy_distinct_divisors(count, n_b)  # case (c)
        if parts is None:
            # paper's greedy infeasible: single stage with idle banks
            bph = n_b // count
            stages.append(Stage(
                heads=tuple(range(head0, head0 + count)),
                banks_per_head=bph,
                idle_banks=n_b - bph * count))
            head0 += count
            return
        for part in parts:
            stages.append(Stage(
                heads=tuple(range(head0, head0 + part)),
                banks_per_head=n_b // part))
            head0 += part

    if n_h <= n_b:
        emit_subset(n_h)
    else:  # case (b): sequential pipeline of <=n_b-head subsets
        rest = n_h
        while rest > 0:
            emit_subset(min(rest, n_b))
            rest -= min(rest, n_b)

    plan = MappingPlan(n_heads=n_h, n_banks=n_b, stages=tuple(stages))
    plan.validate()
    return plan
