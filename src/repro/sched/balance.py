"""Cross-head load balancing with memory-compute co-placement (paper §IV-B).

Workload model (tokens touched per decode step per head):
  streaming head:  sink + local
  retrieval head:  sink + local + select_budget (+ page-metadata scan)

Within a tile, retrieval-head KV operations are spread over all member
banks (co-placement); with interleaved storage each bank receives an equal
1/|tile| share regardless of which pages were selected. These planners are
consumed by the hbsim cycle model (Fig 11) and by tests; on the TPU side
the same decision is realized as the KV-cache sharding layout (see
runtime/sharding.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.configs.base import H2ealConfig
from repro.sched.tiling import Tile


def head_load(kind: str, h2: H2ealConfig, metadata_scan_pages: int = 0) -> float:
    """Tokens of KV touched per decode step for one head."""
    if kind == "streaming":
        return h2.sink + h2.local
    # retrieval: sink+local+selected pages, plus the metadata pass reads
    # 2 d-vectors per page (≈ 2/page_size of a token's K bytes per page)
    meta_cost = 2.0 * metadata_scan_pages / h2.page_size
    return h2.sink + h2.local + h2.select_budget + meta_cost


@dataclass(frozen=True)
class BankLoad:
    bank: tuple
    load: float


def unbalanced_loads(tiles: Sequence[Tile], kinds: Dict[tuple, str],
                     h2: H2ealConfig, pages: int = 0) -> List[BankLoad]:
    """Naive one-head-per-bank placement: each bank carries its own head."""
    return [BankLoad(bank=b, load=head_load(kinds[b], h2, pages))
            for t in tiles for b in t.members]


def balanced_loads(tiles: Sequence[Tile], kinds: Dict[tuple, str],
                   h2: H2ealConfig, pages: int = 0) -> List[BankLoad]:
    """Co-placement: every tile's total load is split evenly across its
    member banks (interleaved KV storage makes the split exact for any
    page selection)."""
    out: List[BankLoad] = []
    for t in tiles:
        total = sum(head_load(kinds[b], h2, pages) for b in t.members)
        share = total / len(t.members)
        out.extend(BankLoad(bank=b, load=share) for b in t.members)
    return out


def imbalance(loads: Sequence[BankLoad]) -> float:
    """max/mean load ratio (1.0 = perfectly balanced)."""
    return load_imbalance([x.load for x in loads])


# ---------------------------------------------------------------------------
# Ragged batches (continuous batching, repro/serving)
#
# The uniform model above assumes every sequence in the batch sits at the
# same (long) context. Under continuous batching each slot has its own
# context length: short slots haven't filled their sink+local windows yet,
# and a retrieval head's selected budget is capped by how much selectable
# KV exists. These per-slot loads let the tiling/assignment (and the hbsim
# cycle model) score the batch the engine is actually serving.
# ---------------------------------------------------------------------------


def slot_head_load(kind: str, h2: H2ealConfig, ctx: int) -> float:
    """Tokens of KV touched per decode step for one head of ONE slot at
    context length ``ctx`` (uniform `head_load` is the ctx→∞ limit, up to
    its externally-supplied metadata page count)."""
    ctx = int(ctx)
    if kind == "streaming":
        return float(min(ctx, h2.sink + h2.local))
    live_pages = -(-ctx // h2.page_size)
    meta_cost = 2.0 * live_pages / h2.page_size
    return float(min(ctx, h2.sink + h2.local + h2.select_budget)) + meta_cost


def ragged_head_load(kind: str, h2: H2ealConfig,
                     ctx_lengths: Sequence[int]) -> float:
    """Total per-step load of one head over a ragged batch (sum of the
    batch's live slots; pass only active slots' lengths)."""
    return sum(slot_head_load(kind, h2, c) for c in ctx_lengths)


def ragged_loads(tiles: Sequence[Tile], kinds: Dict[tuple, str],
                 h2: H2ealConfig, ctx_lengths: Sequence[int],
                 *, balanced: bool = True) -> List[BankLoad]:
    """Per-bank loads for a ragged batch.

    balanced=True spreads each tile's total across its members (the
    co-placement split is exact for any page selection AND any per-slot
    length, since interleaved storage stripes every slot's pages the same
    way); balanced=False is the naive one-head-per-bank placement.
    """
    out: List[BankLoad] = []
    for t in tiles:
        members = t.members
        per_head = {b: ragged_head_load(kinds[b], h2, ctx_lengths)
                    for b in members}
        if balanced:
            share = sum(per_head.values()) / len(members)
            out.extend(BankLoad(bank=b, load=share) for b in members)
        else:
            out.extend(BankLoad(bank=b, load=per_head[b]) for b in members)
    return out


def occupancy(active: Sequence[bool]) -> float:
    """Fraction of batch slots currently serving a request."""
    n = len(active)
    return sum(bool(a) for a in active) / n if n else 0.0


# ---------------------------------------------------------------------------
# Ragged placement scoring (continuous batching under sharded co-placement)
#
# Under the interleaved page striping (paper Fig 7b / coplace_shmap), page
# p of EVERY slot lives on device p % n_shards. A slot with `pages` live
# pages therefore loads device d with ceil((pages - d) / n_shards) pages —
# the floor share plus one remainder page on the first `pages % n_shards`
# devices. Remainders from different slots stack on the SAME low-indexed
# devices, so a ragged batch is per-device imbalanced by up to one page
# per slot. Admission can counteract this by picking the queued request
# whose page count flattens the remainder pile-up (the paper's §IV-C
# balancing applied to the batch dimension; consumed by
# serving.Engine(admission="balanced")).
# ---------------------------------------------------------------------------


def slot_pages(ctx: int, page_size: int) -> int:
    """Live pages of one slot at context length ``ctx``."""
    return -(-int(ctx) // page_size) if ctx > 0 else 0


def device_page_loads(ctx_lengths: Sequence[int], *, n_shards: int,
                      page_size: int,
                      hot_cap: int | None = None) -> List[int]:
    """Per-device resident-page counts of a ragged batch under round-robin
    (interleaved) page→device striping.

    ``hot_cap`` models tiered residency (core/cache.TieredPagedCache): a
    slot keeps at most ``hot_cap`` pages device-resident regardless of
    its context length — cold pages live in the far store and cost no
    device memory — so admission under a tiered engine scores hot-set
    size, not total pages."""
    loads = [0] * n_shards
    for ctx in ctx_lengths:
        pages = slot_pages(ctx, page_size)
        if hot_cap is not None:
            pages = min(pages, int(hot_cap))
        q, r = divmod(pages, n_shards)
        for d in range(n_shards):
            loads[d] += q + (1 if d < r else 0)
    return loads


def chunk_allocation(tokens_done: Sequence[int], tokens_left: Sequence[int],
                     budget: int, *, n_shards: int,
                     page_size: int) -> List[int]:
    """Split one engine step's chunked-prefill token budget across the
    prefilling slots (consumed by serving.Engine's mixed step).

    ``tokens_done[i]`` is slot i's prompt tokens already fed,
    ``tokens_left[i]`` the remainder; slots are given in FIFO (admission)
    order. Grants are page-granular: each round gives one slot tokens up
    to its next page boundary, choosing the slot whose page being
    written lands on the least-loaded device under round-robin page →
    device striping (seeded with the prefilling slots' resident pages;
    FIFO order breaks ties). With ``n_shards == 1`` every device load is
    equal, so the first unfinished slot wins each round — plain FIFO
    fill. Returns the per-slot grant list (sums to
    min(budget, sum(tokens_left))).
    """
    n = len(tokens_left)
    assert len(tokens_done) == n
    alloc = [0] * n
    left = [int(t) for t in tokens_left]
    done = [int(t) for t in tokens_done]
    shards = max(int(n_shards), 1)
    loads = [0] * shards
    for t in done:  # resident pages of partially-fed slots
        pages = -(-t // page_size) if t > 0 else 0
        q, r = divmod(pages, shards)
        for d in range(shards):
            loads[d] += q + (1 if d < r else 0)
    budget = int(budget)
    while budget > 0 and any(l > 0 for l in left):
        best = None
        for i in range(n):
            if left[i] <= 0:
                continue
            d = ((done[i] + alloc[i]) // page_size) % shards
            if best is None or loads[d] < loads[best[1]]:
                best = (i, d)
        i, d = best
        fed = done[i] + alloc[i]
        if fed % page_size == 0:
            loads[d] += 1          # this grant opens a page on device d
        grant = min(left[i], budget, page_size - fed % page_size)
        alloc[i] += grant
        left[i] -= grant
        budget -= grant
    return alloc


def load_imbalance(vals: Sequence[float]) -> float:
    """max/mean of raw load values (1.0 = perfectly balanced)."""
    vals = list(vals)
    mean = sum(vals) / len(vals) if vals else 0.0
    return max(vals) / mean if mean > 0 else 1.0


def admission_score(ctx_lengths: Sequence[int], candidate_ctx: int, *,
                    n_shards: int, page_size: int,
                    hot_cap: int | None = None,
                    spec_tokens: int | None = None,
                    prefill_done: Sequence[int] | None = None,
                    prefill_left: Sequence[int] | None = None,
                    chunk_budget: int | None = None) -> float:
    """Per-device page-load imbalance of the batch AFTER admitting a
    request at context ``candidate_ctx`` next to the live ``ctx_lengths``.
    Lower is better; the engine admits the queued request minimizing it.
    Under a tiered engine ``hot_cap`` caps each slot's scored pages at
    the device-resident hot-set size (see ``device_page_loads``).

    Under speculative decode (``spec_tokens=k``) every slot is scored at
    the page span of one verify step ahead (``ctx + k - 1``): a verify
    step appends up to k tokens before the host can rebalance, so a slot
    sitting just below a page boundary WILL open its next page within
    the current chunk — the score sees the page the chunk commits, not
    the one the host mirror shows.

    Under chunked prefill, pass PREFILLING slots through
    ``prefill_done``/``prefill_left`` (tokens fed / still to come)
    instead of ``ctx_lengths``: they still count at their full eventual
    page span (done + left — the residency they WILL reach), and the
    score additionally sees the IN-FLIGHT prefill compute: one shared
    ``chunk_budget`` is split across the prefilling slots and the
    candidate (``chunk_allocation`` — the allocator the engine's mixed
    step actually runs), and each granted slot adds one unit of load on
    the device its next written page lands on. Two candidates with equal
    eventual spans then split on WHERE their first chunks land — the
    settled-page score alone cannot see that."""
    horizon = max(int(spec_tokens) - 1, 0) if spec_tokens else 0
    done = [int(d) for d in (prefill_done or ())]
    left = [int(t) for t in (prefill_left or ())]
    assert len(done) == len(left), (done, left)
    ctxs = [int(c) + horizon for c in ctx_lengths]
    ctxs.extend(d + t + horizon for d, t in zip(done, left))
    ctxs.append(int(candidate_ctx) + horizon)
    loads = device_page_loads(ctxs, n_shards=n_shards,
                              page_size=page_size, hot_cap=hot_cap)
    if chunk_budget:
        alloc = chunk_allocation(done + [0], left + [int(candidate_ctx)],
                                 int(chunk_budget),
                                 n_shards=max(int(n_shards), 1),
                                 page_size=page_size)
        feed = done + [0]
        for i, grant in enumerate(alloc):
            if grant > 0:
                d = (feed[i] // page_size) % max(int(n_shards), 1)
                loads[d] += 1
    return load_imbalance(loads)
