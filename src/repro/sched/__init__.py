from repro.sched.mapping import (  # noqa: F401
    MappingPlan,
    SlotAssignment,
    Stage,
    map_heads,
    map_slots,
)
from repro.sched.tiling import (  # noqa: F401
    Tile,
    grid_coords,
    head_permutation,
    manhattan,
    solve_tiling,
)
from repro.sched.cost import (  # noqa: F401
    CostModel,
    SlotCost,
    SlotView,
    device_compute_loads,
    slot_bank,
)
from repro.sched.rebalance import (  # noqa: F401
    Migration,
    RebalancePlan,
    plan_rebalance,
)
from repro.sched.windows import (  # noqa: F401
    window_budgets,
)
from repro.sched.balance import (  # noqa: F401
    admission_score,
    balanced_loads,
    chunk_allocation,
    device_page_loads,
    head_load,
    imbalance,
    load_imbalance,
    occupancy,
    ragged_head_load,
    ragged_loads,
    slot_head_load,
    slot_pages,
    unbalanced_loads,
)
