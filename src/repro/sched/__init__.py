from repro.sched.mapping import MappingPlan, Stage, map_heads  # noqa: F401
from repro.sched.tiling import (  # noqa: F401
    Tile,
    grid_coords,
    head_permutation,
    manhattan,
    solve_tiling,
)
from repro.sched.balance import (  # noqa: F401
    balanced_loads,
    head_load,
    imbalance,
    occupancy,
    ragged_head_load,
    ragged_loads,
    slot_head_load,
    unbalanced_loads,
)
