"""Communication-minimal tiling (paper §IV-C.2).

Given bank coordinates on the NoC mesh and each bank's head type
(retrieval / streaming), partition banks into t = min(n_r, n_s) tiles with
|T_i| <= ceil((n_r+n_s)/t), mixing both types, minimizing the maximum
Manhattan distance between retrieval and streaming banks within a tile.

Solved exactly as the paper does — as a flow problem: binary-search the
distance bound D; feasibility is a bipartite b-matching (anchors = banks
of the minority type, capacity tile_size-1) checked with BFS max-flow
(Edmonds–Karp). Grids are tiny (<=16x16), so this is instant.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

Coord = Tuple[int, int]


@dataclass(frozen=True)
class Tile:
    anchor: Coord               # minority-type bank
    members: tuple              # all bank coords in the tile (incl anchor)
    max_dist: int


def manhattan(a: Coord, b: Coord) -> int:
    return abs(a[0] - b[0]) + abs(a[1] - b[1])


def _max_flow(adj: List[List[int]], n: int, src: int, dst: int,
              cap: Dict[Tuple[int, int], int]) -> Dict[Tuple[int, int], int]:
    """Edmonds–Karp; returns flow dict."""
    flow: Dict[Tuple[int, int], int] = {}

    def residual(u, v):
        return cap.get((u, v), 0) - flow.get((u, v), 0) + flow.get((v, u), 0)

    while True:
        parent = {src: None}
        q = deque([src])
        while q and dst not in parent:
            u = q.popleft()
            for v in adj[u]:
                if v not in parent and residual(u, v) > 0:
                    parent[v] = u
                    q.append(v)
        if dst not in parent:
            return flow
        # bottleneck
        path = []
        v = dst
        while parent[v] is not None:
            path.append((parent[v], v))
            v = parent[v]
        aug = min(residual(u, w) for u, w in path)
        for u, w in path:
            back = flow.get((w, u), 0)
            if back >= aug:
                flow[(w, u)] = back - aug
            else:
                flow[(w, u)] = 0
                flow[(u, w)] = flow.get((u, w), 0) + aug - back


def _feasible(anchors: Sequence[Coord], others: Sequence[Coord],
              d_bound: int, cap_per_tile: int):
    """b-matching: every non-anchor bank assigned to an anchor within
    d_bound, anchors take <= cap_per_tile-1. Returns assignment or None."""
    na, no = len(anchors), len(others)
    src, dst = 0, 1 + na + no
    adj: List[List[int]] = [[] for _ in range(na + no + 2)]
    cap: Dict[Tuple[int, int], int] = {}
    for i, a in enumerate(anchors):
        u = 1 + i
        adj[src].append(u)
        adj[u].append(src)
        cap[(src, u)] = cap_per_tile - 1
        for j, o in enumerate(others):
            if manhattan(a, o) <= d_bound:
                v = 1 + na + j
                adj[u].append(v)
                adj[v].append(u)
                cap[(u, v)] = 1
    for j in range(no):
        v = 1 + na + j
        adj[v].append(dst)
        adj[dst].append(v)
        cap[(v, dst)] = 1
    flow = _max_flow(adj, na + no + 2, src, dst, cap)
    total = sum(flow.get((1 + na + j, dst), 0) for j in range(no))
    if total < no:
        return None
    assign: Dict[int, List[int]] = {i: [] for i in range(na)}
    for i in range(na):
        for j in range(no):
            if flow.get((1 + i, 1 + na + j), 0) > 0:
                assign[i].append(j)
    return assign


def solve_tiling(retrieval: Sequence[Coord], streaming: Sequence[Coord]):
    """Partition banks into tiles. Returns (tiles, max_dist)."""
    n_r, n_s = len(retrieval), len(streaming)
    if n_r == 0 or n_s == 0:  # degenerate: single-type — one tile per bank
        banks = list(retrieval) + list(streaming)
        return [Tile(anchor=b, members=(b,), max_dist=0) for b in banks], 0
    t = min(n_r, n_s)
    cap = -(-(n_r + n_s) // t)
    anchors, others = ((retrieval, streaming) if n_r <= n_s
                       else (streaming, retrieval))
    # binary search minimal feasible D
    dists = sorted({manhattan(a, o) for a in anchors for o in others})
    lo, hi = 0, len(dists) - 1
    best = None
    best_d = dists[-1]
    while lo <= hi:
        mid = (lo + hi) // 2
        res = _feasible(anchors, others, dists[mid], cap)
        if res is not None:
            best, best_d = res, dists[mid]
            hi = mid - 1
        else:
            lo = mid + 1
    assert best is not None, "cap >= 2 should always be feasible at max D"
    tiles = []
    for i, a in enumerate(anchors):
        members = (a,) + tuple(others[j] for j in best[i])
        md = max((manhattan(a, m) for m in members[1:]), default=0)
        tiles.append(Tile(anchor=a, members=members, max_dist=md))
    return tiles, best_d


def grid_coords(rows: int, cols: int) -> List[Coord]:
    return [(r, c) for r in range(rows) for c in range(cols)]


def head_permutation(alpha_layer, static_sparsity: float):
    """Per-layer kv-head order: retrieval heads (desc α) first.

    Mirrors core.gating.classify_heads for a single layer; used to build
    the model 'plan' from gating output + scheduler placement.
    """
    import numpy as np

    a = np.asarray(alpha_layer)
    return np.argsort(-a, kind="stable").astype("int32")
