"""Rebalance planner: slot migrations that flatten device compute (§IV-B).

The engine admits requests balanced (``sched/balance.py``) but load
drifts afterwards: slots retire, contexts grow, and the streaming /
retrieval head mix makes per-bank compute diverge from the page counts
admission scored. The paper's scheduler re-spreads attention work across
HB banks when this drift appears; our batch-dimension analogue is to
*migrate a slot to a different slot index* so the batch-axis sharding
places its compute on an underloaded bank.

``plan_rebalance`` turns a cost snapshot (``sched/cost.py``) into a
small, safe move list:

  * targets come from greedy-LPT (``map_slots``) over total slot
    compute — the same 4/3-approximation the balance report scores
    placements with;
  * a move only lands in a FREE slot index inside the target bank's
    block (a single donated copy-then-reset primitive in the engine; no
    live-live swaps, so a half-applied plan is still a valid state);
  * executed moves free their source index for later candidates within
    the same plan;
  * hysteresis — the plan is empty unless it improves the max/mean
    imbalance by at least ``min_gain`` (the engine adds a step cooldown
    on top), so the planner never thrashes on noise.

Token traces are bit-exact under any plan: a migration copies the cache
rows, lengths, and sampling lanes verbatim, and sampling keys are owned
by (seed, uid) — not the slot index (see docs/serving.md §Rebalancing).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.sched.balance import load_imbalance
from repro.sched.cost import SlotCost, device_compute_loads, slot_bank
from repro.sched.mapping import map_slots


@dataclass(frozen=True)
class Migration:
    """One slot move: ``src`` slot index → free ``dst`` slot index."""

    src: int
    dst: int
    uid: int
    compute: float   # the moved slot's scored compute (for reporting)


@dataclass(frozen=True)
class RebalancePlan:
    moves: Tuple[Migration, ...]
    imbalance_before: float
    imbalance_after: float

    @property
    def gain(self) -> float:
        return self.imbalance_before - self.imbalance_after


def plan_rebalance(costs: Sequence[SlotCost], free_slots: Sequence[int], *,
                   n_banks: int, max_batch: int,
                   page_stripe_shards: int = 1,
                   min_gain: float = 0.0) -> RebalancePlan:
    """Propose slot migrations flattening per-bank compute.

    ``costs`` are the live slots' scores (``CostModel.slot_costs``),
    ``free_slots`` the currently unoccupied slot indices. Deterministic:
    ties in LPT keep index order and free destinations are taken lowest
    index first."""
    costs = list(costs)
    before = load_imbalance(device_compute_loads(
        costs, n_banks=n_banks, max_batch=max_batch,
        page_stripe_shards=page_stripe_shards))
    if len(costs) < 2 or n_banks <= 1 or not free_slots:
        return RebalancePlan((), before, before)

    target = map_slots([c.compute for c in costs], n_banks)
    free_by_bank: List[List[int]] = [[] for _ in range(n_banks)]
    for s in sorted(set(int(f) for f in free_slots)):
        free_by_bank[slot_bank(s, n_banks=n_banks, max_batch=max_batch)] \
            .append(s)

    moves: List[Migration] = []
    placed = {c.slot: c.slot for c in costs}
    for bank, members in enumerate(target.banks):
        for i in members:
            c = costs[i]
            cur = slot_bank(placed[c.slot], n_banks=n_banks,
                            max_batch=max_batch)
            if cur == bank or not free_by_bank[bank]:
                continue
            dst = free_by_bank[bank].pop(0)
            moves.append(Migration(src=placed[c.slot], dst=dst, uid=c.uid,
                                   compute=c.compute))
            # the vacated source index is free for later candidates
            free_by_bank[cur].append(placed[c.slot])
            free_by_bank[cur].sort()
            placed[c.slot] = dst

    if not moves:
        return RebalancePlan((), before, before)
    sim = [SlotCost(slot=placed[c.slot], uid=c.uid, phase=c.phase,
                    compute=c.compute, paged_compute=c.paged_compute,
                    pages=c.pages) for c in costs]
    after = load_imbalance(device_compute_loads(
        sim, n_banks=n_banks, max_batch=max_batch,
        page_stripe_shards=page_stripe_shards))
    if before - after < float(min_gain):
        return RebalancePlan((), before, before)
    return RebalancePlan(tuple(moves), before, after)
