"""Pluggable attention/serve-cache layout backends (paper §IV-B).

H²EAL's core claim is that different heads and different memory layouts
want different attention strategies. This module is the single dispatch
point for that choice: every serve-cache layout is an
:class:`AttentionLayout` entry in a registry, and everything above this
layer (``models/transformer.py``, ``serving/engine.py``,
``runtime/serve.py``, the CLIs and benchmarks) resolves layouts by name
— placement is data, not control flow. Unknown names raise with the
registered list, mirroring ``kernels/ops.resolve_impl``.

The protocol (one class ≈ 50 lines; see docs/serving.md for a worked
example):

* ``plan(cfg, mesh) -> LayoutPlan`` — construction-time planning:
  resolve/validate the mesh (or build a default one), declare the
  capacity rounding quantum, whether the batched serve state must be
  device_put into a sharded placement, and the shard count balanced
  admission should score against. Mesh problems surface HERE, not at
  the first decode step.
* ``cache_axes(kind, batch_ok)`` — the paged-cache leaf placement
  (axis names with a ``"batch"`` placeholder) that
  ``runtime/sharding.state_shardings`` turns into PartitionSpecs.
* ``prefill(spec, k, v, length, capacity, perm)`` — build the decode
  state (paged + stream caches) from prefill K/V, in whatever physical
  page order the layout wants.
* ``prefill_chunk(spec, state, inputs)`` — append one prompt chunk of a
  chunked (slot-resident) prefill directly into the layout's sharded
  caches and attend it causally, over a single :class:`PrefillInputs`
  pytree (mirroring ``DecodeInputs``). This is how the serving engine
  prefills without ever leaving the batched sharded state — no batch-1
  unsharded prefill + pack.
* ``decode(spec, state, inputs)`` / ``ragged_decode(spec, state,
  inputs)`` — one decode step against the layout's cache placement.
  Both take a single :class:`DecodeInputs` pytree instead of the long
  positional signatures of ``core/hybrid_attention.py`` (which remain
  as the underlying bodies and as deprecated direct-call aliases for
  one release).

Registered layouts:

  default        — single-program path, no mesh required. The pure
                   algorithm (paper §IV-A); also the token-exactness
                   oracle every other layout is tested against.
  head           — GSPMD baseline head parallelism: kv-heads → 'model',
                   batch → 'data' (paper Fig 3a).
  coplace        — GSPMD memory-compute co-placement: pages → 'model'
                   (paper §IV-B); decode math is the default body,
                   placement comes entirely from ``cache_axes``.
  interleave     — co-placement + interleaved storage: pages → 'model'
                   AND within-page tokens → 'data' (paper Fig 7b).
                   Supports ragged continuous-batching decode purely
                   through this registry entry — the engine has no
                   interleave-specific code.
  coplace_shmap  — explicit shard_map realization of co-placement with
                   round-robin physical page striping: per-device
                   partial softmax over locally-owned pages merged with
                   a cross-device log-sum-exp combine
                   (core/hybrid_attention.py::_paged_decode_coplace).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hybrid_attention as hattn

Array = jax.Array

LAYOUT_DEFAULT = "default"
LAYOUT_HEAD = "head"
LAYOUT_COPLACE = "coplace"
LAYOUT_INTERLEAVE = "interleave"
LAYOUT_COPLACE_SHMAP = "coplace_shmap"

# legacy spellings accepted for one release (None/"auto" predate the
# registry; the engine and launch CLIs used them for the default path).
# resolve_layout() emits a one-shot DeprecationWarning per spelling,
# mirroring kernels/ops.resolve_impl's impl="kernel" treatment.
_ALIASES = {None: LAYOUT_DEFAULT, "auto": LAYOUT_DEFAULT}
_warned_aliases: set = set()


# ---------------------------------------------------------------------------
# The one decode-step input contract
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DecodeInputs:
    """Everything a layout's decode hook consumes, as one pytree.

    q: (B, Hq, D) roped at each slot's position; k_new/v_new: (B, Hkv, D).
    lengths: context BEFORE this token — scalar (lockstep) or (B,)
    per-slot (continuous batching). active/need_select: the ragged
    path's per-slot masks (None on the lockstep path); see
    core/hybrid_attention.py::decode_attention for their exact
    semantics.
    """

    q: Array
    k_new: Array
    v_new: Array
    lengths: Array
    active: Optional[Array] = None
    need_select: Optional[Array] = None

    @property
    def is_ragged(self) -> bool:
        return (self.active is not None
                or jnp.asarray(self.lengths).ndim == 1)


jax.tree_util.register_dataclass(
    DecodeInputs,
    data_fields=["q", "k_new", "v_new", "lengths", "active", "need_select"],
    meta_fields=[])


@dataclasses.dataclass
class PrefillInputs:
    """Everything a layout's ``prefill_chunk`` hook consumes, as one
    pytree (the chunked-prefill mirror of :class:`DecodeInputs`).

    q: (B, C, Hq, D) roped at each slot's chunk positions; k_new/v_new:
    (B, C, Hkv, D). start: (B,) context length before the chunk (the
    slot's tokens-so-far); chunk_len: (B,) valid tokens in this chunk
    (rows past it are padding); active: (B,) bool — slots taking a
    chunk this step (None = all).
    """

    q: Array
    k_new: Array
    v_new: Array
    start: Array
    chunk_len: Array
    active: Optional[Array] = None


jax.tree_util.register_dataclass(
    PrefillInputs,
    data_fields=["q", "k_new", "v_new", "start", "chunk_len", "active"],
    meta_fields=[])


@dataclasses.dataclass
class VerifyInputs:
    """Everything a layout's speculative ``verify_chunk`` hook consumes
    (PR 8). q/k_new/v_new: (B, k, ·, D) roped at positions start ..
    start+k-1; start: (B,) context length before the chunk; active: (B,)
    bool live slots; need_select: (B,) bool per-slot share-window phase —
    the chunk's one selection refresh is gated per slot exactly like a
    decode select step."""

    q: Array
    k_new: Array
    v_new: Array
    start: Array
    active: Optional[Array] = None
    need_select: Optional[Array] = None


jax.tree_util.register_dataclass(
    VerifyInputs,
    data_fields=["q", "k_new", "v_new", "start", "active", "need_select"],
    meta_fields=[])


# ---------------------------------------------------------------------------
# Construction-time plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayoutPlan:
    """What the serving engine needs to know before the first step.

    layout           — canonical registry name (feeds state_shardings).
    mesh             — resolved mesh (None = no mesh; single-program).
    capacity_quantum — cache capacity (tokens) must round up to a
                       multiple of this (sharded page dims need a whole
                       number of pages per device).
    shard_state      — the batched serve state must be device_put into
                       its sharded placement at construction and the
                       decode/pack jits must pin out_shardings (the
                       zero-recompile invariant under sharding).
    balance_shards   — shard count ``admission="balanced"`` scores
                       per-device page loads against (1 = FIFO).
    page_stripe_shards — physical page→slot striping factor of the
                       layout's paged cache (1 = physical page order is
                       logical page order). coplace_shmap stripes pages
                       round-robin over the mesh 'model' axis; the
                       tiered-residency controller (core/cache.py
                       TieredPagedCache) reads this so every registered
                       layout inherits hot/cold page spilling with the
                       correct physical pin mapping.
    """

    layout: str
    mesh: Any = None
    capacity_quantum: int = 1
    shard_state: bool = False
    balance_shards: int = 1
    page_stripe_shards: int = 1

    def round_capacity(self, tokens: int) -> int:
        q = max(int(self.capacity_quantum), 1)
        return -(-int(tokens) // q) * q

    def phys_page(self, logical: int, n_pages: int) -> int:
        """Physical page slot of logical page ``logical`` under this
        layout's striping (identity when ``page_stripe_shards == 1``)."""
        from repro.core import paging

        if self.page_stripe_shards <= 1:
            return int(logical)
        return int(paging.interleave_slot(logical, n_pages,
                                          self.page_stripe_shards))

    def state_shardings(self, cfg, state, *, batch_size: int | None = None):
        """NamedSharding pytree for a batched serve state."""
        from repro.runtime import sharding as shardlib

        return shardlib.state_shardings(cfg, self.mesh, state,
                                        layout=self.layout,
                                        batch_size=batch_size)


# ---------------------------------------------------------------------------
# The layout protocol + registry
# ---------------------------------------------------------------------------


class AttentionLayout:
    """Base class / protocol for serve-cache layouts. Subclass, set
    ``name``, override the hooks that differ, and ``register_layout()``
    an instance — the engine, step builders, CLIs, benchmarks and the
    conformance tests pick the new entry up by name."""

    name: str = "abstract"
    #: pages are distributed across devices — balanced admission has an
    #: effect and the benchmark enables it by default
    shards_pages: bool = False

    # -- construction-time ------------------------------------------------
    def plan(self, cfg, mesh=None) -> LayoutPlan:
        raise NotImplementedError(self.name)

    def cache_axes(self, kind: str, *, batch_ok: bool) -> Tuple:
        """Axis names for a paged-cache leaf (``"batch"`` placeholder is
        resolved by runtime/sharding.py). kind: "pages" (B,Hr,C,P,D),
        "tau" (B,Hr,C,D) or "meta" (B,Hr,C)."""
        raise NotImplementedError(self.name)

    # -- prefill ----------------------------------------------------------
    def prefill(self, spec, k, v, length, capacity, perm=None) -> Dict:
        """Build the decode state {"paged", "stream"} from prefill K/V."""
        raise NotImplementedError(self.name)

    def prefill_chunk(self, spec, state: Dict, inputs: PrefillInputs, *,
                      perm=None):
        """Chunked prefill: append one prompt chunk directly into the
        layout's caches and attend it causally
        -> (out (B, C, Hq, D), new state)."""
        raise NotImplementedError(
            f"layout {self.name!r} does not support chunked prefill")

    # -- decode -----------------------------------------------------------
    def decode(self, spec, state: Dict, inputs: DecodeInputs, *,
               do_select: bool, perm=None):
        """Lockstep decode step -> (out (B,Hq,D), new state)."""
        raise NotImplementedError(self.name)

    def ragged_decode(self, spec, state: Dict, inputs: DecodeInputs, *,
                      do_select: bool, perm=None):
        """Continuous-batching decode step (per-slot lengths/active/
        need_select) -> (out, new state)."""
        raise NotImplementedError(
            f"layout {self.name!r} does not support ragged "
            f"(continuous-batching) decode")

    # -- fused decode windows (PR 10) --------------------------------------
    def decode_window(self, body, carry, xs, *, length: int):
        """Run ``length`` reuse decode steps as one fused program.

        ``body`` is a ``lax.scan``-shaped step built by
        runtime/serve.make_fused_window_step: its per-iteration decode
        math routes through this layout's own ``ragged_decode`` /
        ``prefill_chunk`` hooks, so the default scan realization is
        correct for every registry entry — including shard_map bodies
        (``coplace_shmap``), which scan like any other traced callee. A
        layout only overrides this to change HOW the window iterates
        (e.g. an unrolled or pipelined realization), never the step
        math."""
        return jax.lax.scan(body, carry, xs, length=length)

    # -- speculative verify (PR 8) ---------------------------------------
    def verify_chunk(self, spec, state: Dict, inputs: "VerifyInputs", *,
                     perm=None):
        """Attend k drafted tokens as k decode steps over the PRE-append
        caches (no KV mutation; selection/importance refresh only)
        -> (out (B, k, Hq, D), new state)."""
        raise NotImplementedError(
            f"layout {self.name!r} does not support speculative verify")

    def verify_append(self, spec, state: Dict, inputs: "VerifyInputs",
                      accepted, *, perm=None):
        """Commit the accepted prefix of a verified chunk (ragged chunk
        appends) -> new state."""
        raise NotImplementedError(
            f"layout {self.name!r} does not support speculative verify")


_REGISTRY: Dict[str, AttentionLayout] = {}


def register_layout(layout: AttentionLayout) -> AttentionLayout:
    """Register a layout instance under ``layout.name`` (last wins)."""
    _REGISTRY[layout.name] = layout
    return layout


def available_layouts() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _lookup(name) -> AttentionLayout:
    """Canonicalize (silently) and fetch; raise ValueError if unknown."""
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown attention layout {name!r}; registered layouts: "
            f"{', '.join(available_layouts())}")
    return _REGISTRY[name]


def resolve_layout(name) -> str:
    """Canonicalize a layout name; raise ValueError if unknown.

    The pre-registry spellings ``None`` and ``"auto"`` resolve to
    ``"default"`` but emit a DeprecationWarning once per process (per
    spelling) — they will be removed after one release. Canonical names
    resolve silently.
    """
    if name in _ALIASES:
        canonical = _ALIASES[name]
        if name not in _warned_aliases:
            _warned_aliases.add(name)
            warnings.warn(
                f"layout={name!r} is a deprecated alias for "
                f"{canonical!r} and will be removed; pass "
                f"{canonical!r} instead", DeprecationWarning,
                stacklevel=2)
    return _lookup(name).name


def get_layout(name) -> AttentionLayout:
    """Fetch a layout instance by name. Unlike ``resolve_layout`` this is
    the internal (model-layer) lookup: legacy aliases canonicalize
    silently — the deprecation nudge fires once at the user-facing
    resolution sites (Engine construction, step builders, CLIs)."""
    return _lookup(name)


def dispatch_decode(layout, spec, state: Dict, inputs: DecodeInputs, *,
                    do_select: bool, perm=None):
    """Route one decode step to ``layout``'s decode or ragged_decode hook
    depending on ``inputs.is_ragged`` (trace-time static)."""
    lay = get_layout(layout)
    fn = lay.ragged_decode if inputs.is_ragged else lay.decode
    return fn(spec, state, inputs, do_select=do_select, perm=perm)


def dispatch_decode_window(layout, body, carry, xs, *, length: int):
    """Route a fused decode window (a scan over reuse-step bodies built
    from ``dispatch_decode``) to ``layout``'s decode_window hook."""
    return get_layout(layout).decode_window(body, carry, xs, length=length)


def dispatch_prefill_chunk(layout, spec, state: Dict,
                           inputs: PrefillInputs, *, perm=None):
    """Route one chunked-prefill step to ``layout``'s prefill_chunk
    hook."""
    return get_layout(layout).prefill_chunk(spec, state, inputs, perm=perm)


def dispatch_verify_chunk(layout, spec, state: Dict, inputs: VerifyInputs,
                          *, perm=None):
    """Route one speculative verify attention pass to ``layout``'s
    verify_chunk hook."""
    return get_layout(layout).verify_chunk(spec, state, inputs, perm=perm)


def dispatch_verify_append(layout, spec, state: Dict, inputs: VerifyInputs,
                           accepted, *, perm=None):
    """Route the accepted-prefix commit of a verified chunk to
    ``layout``'s verify_append hook."""
    return get_layout(layout).verify_append(spec, state, inputs, accepted,
                                            perm=perm)


# ---------------------------------------------------------------------------
# Registered layouts
# ---------------------------------------------------------------------------


class DefaultLayout(AttentionLayout):
    """Single-program path: no mesh, no sharding, the §IV-A algorithm as
    plain jittable JAX. The oracle every other layout is compared to."""

    name = LAYOUT_DEFAULT

    def plan(self, cfg, mesh=None) -> LayoutPlan:
        # a caller-provided mesh is kept ambient (e.g. sharding hints)
        # but the state stays unsharded and capacity unrounded
        return LayoutPlan(layout=self.name, mesh=mesh)

    def cache_axes(self, kind: str, *, batch_ok: bool) -> Tuple:
        nd = {"pages": 5, "tau": 4, "meta": 3}[kind]
        return ("batch",) + (None,) * (nd - 1)

    def prefill(self, spec, k, v, length, capacity, perm=None) -> Dict:
        paged, stream = hattn.init_decode_state(spec, k, v, length,
                                                capacity, perm)
        return {"paged": paged, "stream": stream}

    #: physical page→slot striping factor for chunk appends (the
    #: GSPMD layouts keep logical page order; coplace_shmap overrides)
    def _chunk_phys_shards(self) -> int:
        return 1

    def prefill_chunk(self, spec, state, inputs, *, perm=None):
        out, paged, stream = hattn.chunk_prefill_attention(
            spec, inputs.q, inputs.k_new, inputs.v_new,
            state["paged"], state["stream"], inputs.start,
            inputs.chunk_len, inputs.active, perm=perm,
            phys_shards=self._chunk_phys_shards())
        return out, {"paged": paged, "stream": stream}

    def decode(self, spec, state, inputs, *, do_select, perm=None):
        out, paged, stream = hattn.decode_attention(
            spec, inputs.q, inputs.k_new, inputs.v_new,
            state["paged"], state["stream"], inputs.lengths,
            do_select=do_select, perm=perm, active=inputs.active,
            need_select=inputs.need_select)
        return out, {"paged": paged, "stream": stream}

    # the default body handles scalar and (B,) lengths uniformly
    ragged_decode = decode

    # speculative verify is a single-program body for every layout: like
    # the chunked-prefill body, its masks are driven by absolute
    # positions/metadata, and _chunk_phys_shards() maps the fixed page
    # sections into coplace_shmap's striped physical order — GSPMD
    # partitions the same program for the placed layouts
    def verify_chunk(self, spec, state, inputs, *, perm=None):
        out, paged, stream = hattn.chunk_verify_attention(
            spec, inputs.q, inputs.k_new, inputs.v_new,
            state["paged"], state["stream"], inputs.start,
            inputs.active, inputs.need_select, perm=perm,
            phys_shards=self._chunk_phys_shards())
        return out, {"paged": paged, "stream": stream}

    def verify_append(self, spec, state, inputs, accepted, *, perm=None):
        paged, stream = hattn.chunk_verify_append(
            spec, inputs.k_new, inputs.v_new,
            state["paged"], state["stream"], inputs.start, accepted,
            inputs.active, perm=perm,
            phys_shards=self._chunk_phys_shards())
        return {"paged": paged, "stream": stream}


class _GspmdLayout(DefaultLayout):
    """Shared base for GSPMD-placed layouts: the decode math is the
    default body; the layout lives entirely in ``plan`` +
    ``cache_axes`` (GSPMD partitions the same program differently)."""

    def _default_mesh(self, cfg):
        from repro.runtime.compat import make_mesh

        return make_mesh((1, len(jax.devices())), ("data", "model"))

    def _validate_mesh(self, mesh, axes=("model",)):
        missing = [a for a in axes if a not in mesh.axis_names]
        if missing:
            raise ValueError(
                f"layout {self.name!r} requires a mesh with axis(es) "
                f"{missing} (got {tuple(mesh.axis_names)})")
        return mesh

    def plan(self, cfg, mesh=None) -> LayoutPlan:
        mesh = self._validate_mesh(mesh if mesh is not None
                                   else self._default_mesh(cfg))
        nsh = int(mesh.shape["model"])
        quantum = (cfg.h2eal.page_size * nsh if self.shards_pages else 1)
        return LayoutPlan(layout=self.name, mesh=mesh,
                          capacity_quantum=quantum, shard_state=True,
                          balance_shards=nsh if self.shards_pages else 1)


class HeadLayout(_GspmdLayout):
    """Baseline head parallelism (paper Fig 3a): kv-heads → 'model',
    batch → 'data'. No page distribution, so balanced admission is a
    no-op here."""

    name = LAYOUT_HEAD
    shards_pages = False

    def cache_axes(self, kind: str, *, batch_ok: bool) -> Tuple:
        nd = {"pages": 5, "tau": 4, "meta": 3}[kind]
        return ("batch", "model") + (None,) * (nd - 2)


class CoplaceLayout(_GspmdLayout):
    """GSPMD memory-compute co-placement (paper §IV-B): the page dim →
    'model', so each device holds whole pages of every head."""

    name = LAYOUT_COPLACE
    shards_pages = True

    def cache_axes(self, kind: str, *, batch_ok: bool) -> Tuple:
        nd = {"pages": 5, "tau": 4, "meta": 3}[kind]
        return ("batch", None, "model") + (None,) * (nd - 3)


class InterleaveLayout(CoplaceLayout):
    """Co-placement + interleaved storage (paper Fig 7b): pages →
    'model' AND the within-page token dim → 'data', so every page is
    striped across the data axis. Ragged continuous-batching decode
    works through this entry with zero engine changes: ``plan`` rounds
    the capacity, pins the sharded placement, and the default decode
    body is partitioned by GSPMD."""

    name = LAYOUT_INTERLEAVE

    def _default_mesh(self, cfg):
        from repro.runtime.compat import make_mesh

        n = len(jax.devices())
        # within-page striping needs 'data' | page_size; prefer a real
        # data axis when the device count allows one
        data = 2 if (n % 2 == 0 and cfg.h2eal.page_size % 2 == 0) else 1
        return make_mesh((data, n // data), ("data", "model"))

    def plan(self, cfg, mesh=None) -> LayoutPlan:
        plan = super().plan(cfg, mesh)
        self._validate_mesh(plan.mesh, axes=("model", "data"))
        return plan

    def cache_axes(self, kind: str, *, batch_ok: bool) -> Tuple:
        if kind == "pages" and not batch_ok:
            # batch cannot consume 'data' -> stripe within-page tokens
            return (None, None, "model", "data", None)
        if kind in ("tau", "meta"):
            # Quest min/max metadata + page_start/importance stay
            # replicated: ~1/page_size of the KV bytes, and the pinned
            # jax 0.4.x SPMD partitioner miscompiles (or RET_CHECK
            # fails on) the incremental metadata scatter when their
            # page dim is sharded inside the scanned ragged decode
            # body. Only the KV pages themselves are distributed.
            return (None,) * {"tau": 4, "meta": 3}[kind]
        return super().cache_axes(kind, batch_ok=batch_ok)


class CoplaceShmapLayout(CoplaceLayout):
    """Explicit shard_map realization of interleaved co-placement:
    round-robin physical page→shard striping at prefill, per-device
    partial attention over locally-owned pages, cross-device
    log-sum-exp combine (core/hybrid_attention.py). Same plan and cache
    placement as ``coplace`` — only the prefill page order and the
    decode bodies differ."""

    name = LAYOUT_COPLACE_SHMAP

    def plan(self, cfg, mesh=None) -> LayoutPlan:
        plan = super().plan(cfg, mesh)
        # physical pages are striped round-robin over 'model'; the tiered
        # residency controller needs the stripe to map its logical
        # sink/local pins into physical page space (sel_idx/importance
        # are already physical under this layout)
        return dataclasses.replace(
            plan, page_stripe_shards=int(plan.mesh.shape["model"]))

    @staticmethod
    def _ambient_shards() -> int:
        """Round-robin striping factor from the ambient mesh (prefill and
        chunked prefill both run inside the engine's mesh context)."""
        from repro.runtime import hints

        mesh = hints.current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            return int(mesh.shape["model"])
        return 1

    def prefill(self, spec, k, v, length, capacity, perm=None) -> Dict:
        paged, stream = hattn.init_decode_state(
            spec, k, v, length, capacity, perm,
            interleave_shards=self._ambient_shards())
        return {"paged": paged, "stream": stream}

    # chunk appends land on the same physical round-robin page order the
    # shard_map decode body expects; the chunk attention itself is the
    # single-program body partitioned by GSPMD (positions, not slots,
    # drive its masks — see core/paging.py chunk_* helpers)
    def _chunk_phys_shards(self) -> int:
        return self._ambient_shards()

    def decode(self, spec, state, inputs, *, do_select, perm=None):
        out, paged, stream = hattn.decode_attention_coplace(
            spec, inputs.q, inputs.k_new, inputs.v_new,
            state["paged"], state["stream"], inputs.lengths,
            do_select=do_select, perm=perm, active=inputs.active,
            need_select=inputs.need_select)
        return out, {"paged": paged, "stream": stream}

    ragged_decode = decode


register_layout(DefaultLayout())
register_layout(HeadLayout())
register_layout(CoplaceLayout())
register_layout(InterleaveLayout())
register_layout(CoplaceShmapLayout())
