"""H²EAL hybrid static-dynamic sparse attention (paper §IV-A).

Per attention layer, KV heads are ordered by a per-layer permutation
(produced by the scheduler, sched/tiling.py) so that the first
``n_retrieval`` kv heads are retrieval heads and the rest are streaming
heads. Counts are static (static_sparsity is a global proportion, paper
§V-B), the permutation is data — so every layer lowers to the same program
and the whole stack scans.

Prefill:  retrieval heads -> full causal flash attention;
          streaming heads -> sink+local flash attention.
Decode:   retrieval heads -> page-score -> top-k -> paged attention over
          [sink pages | selected pages | local pages];
          streaming heads -> attention over the sink+local ring buffer.
Selection is recomputed every ``share_window`` steps (``do_select``).

This module holds the attention BODIES. Layout dispatch lives one level
up in core/layouts.py (the AttentionLayout registry + the DecodeInputs
pytree): ``decode_attention`` backs the ``default`` layout (and, via
GSPMD repartitioning of the same program, ``head``/``coplace``/
``interleave``); ``decode_attention_coplace`` backs ``coplace_shmap``.
Calling these functions directly with their long positional signatures
still works but is a deprecated path kept for one release — new code
should go through ``layouts.dispatch_decode``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import H2ealConfig
from repro.core import cache as cachelib
from repro.core import paging
from repro.kernels import ops as kops

Array = jax.Array


@dataclass(frozen=True)
class AttnSpec:
    """Static attention-layer spec (hashable; safe as jit static arg)."""

    n_q: int
    n_kv: int
    head_dim: int
    h2: H2ealConfig
    window: int = 0        # >0: plain sliding-window layer (gemma3 local)
    impl: str = "ref"

    @property
    def group(self) -> int:
        return self.n_q // self.n_kv

    @property
    def n_retrieval(self) -> int:
        if not self.h2.enabled or self.window > 0:
            return self.n_kv
        n_s = round(self.n_kv * self.h2.static_sparsity)
        return max(self.n_kv - n_s, 0)

    @property
    def n_streaming(self) -> int:
        return self.n_kv - self.n_retrieval


def identity_perm(spec: AttnSpec) -> Array:
    return jnp.arange(spec.n_kv, dtype=jnp.int32)


def _permute_kv(x: Array, perm: Array) -> Array:
    """x: (..., Hkv, ...) permuted on the kv-head axis (axis 2 of B,S,H,D
    or axis 1 of B,H,D)."""
    axis = 2 if x.ndim == 4 else 1
    return jnp.take(x, perm, axis=axis)


def _permute_q(q: Array, perm: Array, group: int) -> Array:
    """q: (B, S, Hq, D) or (B, Hq, D): permute q heads following kv groups."""
    if q.ndim == 4:
        b, s, hq, d = q.shape
        qg = q.reshape(b, s, hq // group, group, d)
        qg = jnp.take(qg, perm, axis=2)
        return qg.reshape(b, s, hq, d)
    b, hq, d = q.shape
    qg = q.reshape(b, hq // group, group, d)
    qg = jnp.take(qg, perm, axis=1)
    return qg.reshape(b, hq, d)


def _inverse_perm(perm: Array) -> Array:
    n = perm.shape[0]
    return jnp.zeros((n,), jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill_attention(spec: AttnSpec, q: Array, k: Array, v: Array,
                      perm: Array | None = None) -> Array:
    """q: (B,S,Hq,D); k/v: (B,S,Hkv,D) -> (B,S,Hq,D)."""
    h2 = spec.h2
    if spec.window > 0:  # plain sliding-window layer
        return kops.flash_attention(q, k, v, causal=True, window=spec.window,
                                    impl=spec.impl)
    if not h2.enabled or spec.n_streaming == 0:
        return kops.flash_attention(q, k, v, causal=True, impl=spec.impl)
    if perm is None:
        perm = identity_perm(spec)
    g = spec.group
    nr = spec.n_retrieval
    qp = _permute_q(q, perm, g)
    kp = _permute_kv(k, perm)
    vp = _permute_kv(v, perm)
    outs = []
    if nr > 0:
        outs.append(kops.flash_attention(
            qp[:, :, : nr * g], kp[:, :, :nr], vp[:, :, :nr],
            causal=True, impl=spec.impl))
    if spec.n_streaming > 0:
        outs.append(kops.flash_attention(
            qp[:, :, nr * g:], kp[:, :, nr:], vp[:, :, nr:],
            causal=True, window=h2.local, sink=h2.sink, impl=spec.impl))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    return _permute_q(out, _inverse_perm(perm), g)


def init_decode_state(spec: AttnSpec, k: Array, v: Array, length: int,
                      capacity: int, perm: Array | None = None,
                      interleave_shards: int = 1):
    """Build (PagedCache, StreamCache) from prefill K/V.

    k/v: (B, S, Hkv, D) post-RoPE; length == S (static). capacity: max
    context (tokens) the paged cache must hold. interleave_shards > 1 lays
    pages out round-robin across that many page-dim shards (co-placement).
    """
    h2 = spec.h2
    if perm is None:
        perm = identity_perm(spec)
    kp = jnp.take(k, perm, axis=2)
    vp = jnp.take(v, perm, axis=2)
    nr = spec.n_retrieval
    p = h2.page_size
    num_pages = -(-capacity // p)
    # pad sequence to page multiple for the paged constructor (stream cache
    # is built from the UNPADDED sequence below)
    s = k.shape[1]
    pad = (-s) % p
    kpad, vpad = kp, vp
    if pad:
        kpad = jnp.pad(kp, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vpad = jnp.pad(vp, ((0, 0), (0, pad), (0, 0), (0, 0)))
    paged = cachelib.paged_cache_from_prefill(
        kpad[:, :, :nr], vpad[:, :, :nr], num_pages, p, h2.top_k_pages)
    if pad:  # recompute metadata masking the pad tokens of the last page
        offs = (jnp.arange(num_pages * p) < s).reshape(num_pages, p)
        kpp = paged.k_pages.astype(jnp.float32)
        tau_min = jnp.where(offs[None, None, :, :, None], kpp, jnp.inf).min(3)
        tau_max = jnp.where(offs[None, None, :, :, None], kpp, -jnp.inf).max(3)
        paged = dataclasses.replace(paged, tau_min=tau_min, tau_max=tau_max)
    if interleave_shards > 1:
        # permute the page dim to the interleaved physical layout:
        # physical slot p holds logical page (p % c_loc) * nsh + p // c_loc
        nsh = interleave_shards
        assert num_pages % nsh == 0, (
            f"page capacity {num_pages} must divide by {nsh} shards")
        c_loc = num_pages // nsh
        phys = jnp.arange(num_pages)
        logical_of_phys = (phys % c_loc) * nsh + phys // c_loc
        take = lambda a: jnp.take(a, logical_of_phys, axis=2)
        paged = cachelib.PagedCache(
            k_pages=take(paged.k_pages), v_pages=take(paged.v_pages),
            tau_min=take(paged.tau_min), tau_max=take(paged.tau_max),
            importance=take(paged.importance),
            page_start=take(paged.page_start),
            sel_idx=paged.sel_idx)
    stream = cachelib.stream_cache_from_prefill(
        kp[:, :, nr:], vp[:, :, nr:], sink=h2.sink,
        local_cap=_local_cap(h2), length=length)
    return paged, stream


def chunk_prefill_attention(
    spec: AttnSpec,
    q: Array,                  # (B, C, Hq, D) roped at the chunk positions
    k_new: Array,              # (B, C, Hkv, D) roped
    v_new: Array,              # (B, C, Hkv, D)
    paged: cachelib.PagedCache,
    stream: cachelib.StreamCache,
    start: Array,              # (B,) context length BEFORE the chunk
    chunk_len: Array,          # (B,) valid tokens in the chunk
    active: Array | None = None,   # (B,) bool — slots prefilling this step
    *,
    perm: Array | None = None,
    phys_shards: int = 1,
):
    """One chunked-prefill step: append a prompt chunk into the serve
    caches and attend each chunk token causally over everything before it
    (retrieval heads: full causal, exactly single-shot prefill; streaming
    heads: sink+local). Returns (out (B, C, Hq, D), paged', stream').

    There is no page selection during prefill — selection state
    (sel_idx / importance) is untouched, matching the single-shot
    prefill-then-pack constructor. Rows past ``chunk_len`` (and inactive
    slots) append nothing; their outputs are garbage the caller ignores.
    Touched pages must start from the empty sentinels (the engine resets
    a slot's cache rows at admission), so the incremental min/max
    metadata merge is exact.

    ``phys_shards`` > 1 applies the coplace_shmap round-robin physical
    page order on append; validity is derived from absolute positions
    (in-op for the retrieval body, core/paging.py chunk_* helpers for
    the streaming ring) so the math is identical on every layout. The
    retrieval body is ``kops.chunk_attention_paged`` selected by static
    ``spec.impl`` — ref or the Pallas fused-gather kernel — and attends
    the PRE-append buffer plus the chunk's own KV, so the page scatter
    never serializes before the attention. Numerics: the chunk body
    reassociates float sums differently from the single-shot flash
    prefill, so chunked and packed admission agree to float tolerance —
    greedy traces match off argmax ties (EXPERIMENTS.md §Serving
    experiments).
    """
    h2 = spec.h2
    g = spec.group
    nr = spec.n_retrieval
    if perm is None:
        perm = identity_perm(spec)
    qp = _permute_q(q, perm, g)
    kp = _permute_kv(k_new, perm)
    vp = _permute_kv(v_new, perm)
    b, cch = q.shape[0], q.shape[1]
    act = jnp.ones((b,), bool) if active is None else \
        jnp.asarray(active).reshape(b)
    start = jnp.broadcast_to(start, (b,)).astype(jnp.int32)
    pos_q = paging.chunk_positions(start, cch)              # (B, C)

    outs = []
    if nr > 0:
        # fused pre-append body: the chunk attends [paged buffer ∥ chunk
        # keys] with validity computed from page metadata inside the op
        # (per-key for the buffer, static causal for the chunk) — no
        # (B, H, Cq, T) mask, and the append no longer serializes before
        # the attention. Under coplace_shmap the physical page striping
        # only reorders pages; page_start rides along, so the in-op
        # position math is layout-invariant.
        k_r = kp[:, :, :nr]
        v_r = vp[:, :, :nr]
        outs.append(kops.chunk_attention_paged(
            qp[:, :, : nr * g], paged.k_pages, paged.v_pages,
            paged.page_start, start, k_r, v_r, impl=spec.impl))
        paged = cachelib.paged_cache_append_chunk(
            paged, k_r, v_r, start, chunk_len,
            active=act, phys_shards=phys_shards)
    if spec.n_streaming > 0:
        ns = spec.n_streaming
        k_s = kp[:, :, nr:]                                 # (B, C, Hs, D)
        v_s = vp[:, :, nr:]
        # attend against [pre-append ring ∥ chunk keys]: ring slots can be
        # overwritten WITHIN a chunk (positions local_cap apart share a
        # slot), so the post-append ring would lose keys still inside an
        # early chunk query's window
        kr = jnp.concatenate([stream.k, k_s.transpose(0, 2, 1, 3)], axis=2)
        vr = jnp.concatenate([stream.v, v_s.transpose(0, 2, 1, 3)], axis=2)
        chunk_pos = jnp.broadcast_to(pos_q[:, None, :], (b, ns, cch))
        kpos = jnp.concatenate([stream.pos, chunk_pos], axis=2)
        valid_s = paging.chunk_stream_validity(kpos, pos_q, sink=h2.sink,
                                               local=h2.local)
        outs.append(kops.chunk_attention(qp[:, :, nr * g:], kr, vr, valid_s,
                                         impl=spec.impl))
        stream = cachelib.stream_cache_append_chunk(
            stream, k_s, v_s, start, chunk_len, sink=h2.sink, active=act)
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    return _permute_q(out, _inverse_perm(perm), g), paged, stream


def _local_cap(h2: H2ealConfig) -> int:
    # ring capacity: local window + one page of slack so the boundary page
    # semantics match the paged side
    return h2.local + h2.page_size


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_attention(
    spec: AttnSpec,
    q: Array,                 # (B, Hq, D) roped at position `length`
    k_new: Array,             # (B, Hkv, D) roped
    v_new: Array,             # (B, Hkv, D)
    paged: cachelib.PagedCache,
    stream: cachelib.StreamCache,
    length: Array,            # context BEFORE this token: scalar or (B,)
    *,
    do_select: bool,
    perm: Array | None = None,
    active: Array | None = None,       # (B,) bool — ragged batch only
    need_select: Array | None = None,  # (B,) bool — per-slot share window
):
    """One decode step. Returns (out (B,Hq,D), paged', stream').

    Uniform (lockstep) batches pass a scalar ``length``; the
    continuous-batching engine passes per-slot (B,) lengths plus ``active``
    (inactive slots neither append nor advance — their caches are
    bit-stable) and ``need_select`` (per-slot share-window phase: under the
    ``do_select`` variant only slots whose window expired take the fresh
    page selection / importance update; the rest keep their cached
    selection, exactly as if the select step had not run for them).
    """
    h2 = spec.h2
    g = spec.group
    nr = spec.n_retrieval
    if perm is None:
        perm = identity_perm(spec)
    qp = _permute_q(q, perm, g)
    kp = _permute_kv(k_new, perm)
    vp = _permute_kv(v_new, perm)
    q_r, q_s = qp[:, : nr * g], qp[:, nr * g:]
    k_r, k_s = kp[:, :nr], kp[:, nr:]
    v_r, v_s = vp[:, :nr], vp[:, nr:]
    ctx = length + 1

    outs = []
    if nr > 0:
        paged = cachelib.paged_cache_append(paged, k_r, v_r, length,
                                            active=active)
        if do_select:
            scores = paging.score_pages(
                q_r, paged.tau_min, paged.tau_max, paged.page_start, ctx,
                sink=h2.sink, local=h2.local, page=h2.page_size,
                impl=spec.impl)
            sel = paging.select_pages(scores, h2.top_k_pages)
            imp = paging.accumulate_importance(paged.importance, scores)
            if need_select is not None:
                ns = need_select[:, None, None]
                sel = jnp.where(ns, sel, paged.sel_idx)
                imp = jnp.where(ns, imp, paged.importance)
            paged = dataclasses.replace(paged, sel_idx=sel, importance=imp)
        slots = paging.attended_page_slots(
            paged.sel_idx, ctx, sink=h2.sink, local=h2.local,
            page=h2.page_size)
        gk, gv = paging.gather_pages(paged.k_pages, paged.v_pages, slots)
        valid = paging.token_validity(
            slots, paged.page_start, ctx, sink=h2.sink, local=h2.local,
            page=h2.page_size, top_k=h2.top_k_pages)
        outs.append(kops.paged_attention(q_r, gk, gv, valid, impl=spec.impl))
    if spec.n_streaming > 0:
        stream = cachelib.stream_cache_append(
            stream, k_s, v_s, length, sink=h2.sink, active=active)
        # exact sink+local mask (ring carries one page of slack)
        ctx_b = jnp.broadcast_to(jnp.asarray(ctx, jnp.int32),
                                 (q.shape[0],))[:, None, None]
        valid_s = (stream.pos >= 0) & (
            (stream.pos < h2.sink) | (stream.pos >= ctx_b - h2.local))
        outs.append(kops.paged_attention(
            q_s, stream.k, stream.v, valid_s, impl=spec.impl))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    out = _permute_q(out, _inverse_perm(perm), g)
    return out, paged, stream


# ---------------------------------------------------------------------------
# Speculative verify (PR 8): k decode steps in one chunked forward
# ---------------------------------------------------------------------------


def chunk_verify_attention(
    spec: AttnSpec,
    q: Array,                  # (B, k, Hq, D) roped at start .. start+k-1
    k_new: Array,              # (B, k, Hkv, D) roped
    v_new: Array,              # (B, k, Hkv, D)
    paged: cachelib.PagedCache,
    stream: cachelib.StreamCache,
    start: Array,              # (B,) context length BEFORE the chunk
    active: Array | None = None,
    need_select: Array | None = None,
    *,
    perm: Array | None = None,
    phys_shards: int = 1,
):
    """Verify k drafted tokens: each chunk query attends exactly what its
    sequential decode step would, WITHOUT mutating the KV pages or the
    stream ring (attend-before-append — acceptance decides how much of the
    chunk ``chunk_verify_append`` later commits, so no rollback of the
    non-invertible tau scatter-min/max is ever needed). Returns
    (out (B, k, Hq, D), paged', stream) where paged' carries only the
    refreshed selection / importance (gated by ``need_select & active``;
    gated-off slots keep them bit-stable, exactly a reuse step).

    Selection is scored once per chunk with query 0 at context start+1 —
    the same query, context, and (because the page receiving position
    start is never selectable) the same tau metadata the sequential select
    step uses, so the refreshed selection is bitwise that of the
    sequential engine; max_emit clamping in the engine guarantees no
    share-window boundary falls inside a chunk. Keys come from the
    gathered [sink | selected | local] buffer (per-query sectioning via
    paging.verify_token_validity) concatenated with the chunk's own keys
    under a causal triangle. ``phys_shards`` > 1 maps the fixed sections
    through the coplace_shmap physical page order; scoring and top-k read
    physical-order metadata directly (page_start carries absolute
    positions), so this single program is layout-transparent, like
    chunk_prefill_attention.
    """
    h2 = spec.h2
    g = spec.group
    nr = spec.n_retrieval
    if perm is None:
        perm = identity_perm(spec)
    qp = _permute_q(q, perm, g)
    kp = _permute_kv(k_new, perm)
    vp = _permute_kv(v_new, perm)
    b, kch = q.shape[0], q.shape[1]
    act = jnp.ones((b,), bool) if active is None else \
        jnp.asarray(active).reshape(b)
    need = jnp.ones((b,), bool) if need_select is None else \
        jnp.asarray(need_select).reshape(b)
    start = jnp.broadcast_to(start, (b,)).astype(jnp.int32)
    pos_q = paging.chunk_positions(start, kch)              # (B, k)
    ctx1 = start + 1

    outs = []
    if nr > 0:
        q_r = qp[:, :, : nr * g]                            # (B, k, HqR, D)
        k_r = kp[:, :, :nr]
        v_r = vp[:, :, :nr]
        scores = paging.score_pages(
            q_r[:, 0], paged.tau_min, paged.tau_max, paged.page_start,
            ctx1, sink=h2.sink, local=h2.local, page=h2.page_size,
            impl=spec.impl)
        sel = paging.select_pages(scores, h2.top_k_pages)
        imp = paging.accumulate_importance(paged.importance, scores)
        ns = (need & act)[:, None, None]
        sel = jnp.where(ns, sel, paged.sel_idx)
        imp = jnp.where(ns, imp, paged.importance)
        paged = dataclasses.replace(paged, sel_idx=sel, importance=imp)
        slots = paging.verify_attended_slots(
            paged.sel_idx, ctx1, sink=h2.sink, local=h2.local,
            page=h2.page_size, capacity=paged.k_pages.shape[2],
            n_shards=phys_shards)
        gk, gv = paging.gather_pages(paged.k_pages, paged.v_pages, slots)
        valid_p = paging.verify_token_validity(
            slots, paged.page_start, start, pos_q, sink=h2.sink,
            local=h2.local, page=h2.page_size, top_k=h2.top_k_pages)
        kr = jnp.concatenate(
            [gk, k_r.transpose(0, 2, 1, 3).astype(gk.dtype)], axis=2)
        vr = jnp.concatenate(
            [gv, v_r.transpose(0, 2, 1, 3).astype(gv.dtype)], axis=2)
        tail = jnp.tril(jnp.ones((kch, kch), bool))         # key i <= query j
        valid = jnp.concatenate([
            valid_p,
            jnp.broadcast_to(tail[None, None], (b, nr, kch, kch)),
        ], axis=3)
        outs.append(kops.chunk_attention(q_r, kr, vr, valid, impl=spec.impl))
    if spec.n_streaming > 0:
        n_s = spec.n_streaming
        k_s = kp[:, :, nr:]
        v_s = vp[:, :, nr:]
        kr = jnp.concatenate([stream.k, k_s.transpose(0, 2, 1, 3)], axis=2)
        vr = jnp.concatenate([stream.v, v_s.transpose(0, 2, 1, 3)], axis=2)
        chunk_pos = jnp.broadcast_to(pos_q[:, None, :], (b, n_s, kch))
        kpos = jnp.concatenate([stream.pos, chunk_pos], axis=2)
        valid_s = paging.chunk_stream_validity(kpos, pos_q, sink=h2.sink,
                                               local=h2.local)
        outs.append(kops.chunk_attention(qp[:, :, nr * g:], kr, vr, valid_s,
                                         impl=spec.impl))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    return _permute_q(out, _inverse_perm(perm), g), paged, stream


def chunk_verify_append(
    spec: AttnSpec,
    k_new: Array,              # (B, k, Hkv, D) roped — the VERIFIED chunk
    v_new: Array,
    paged: cachelib.PagedCache,
    stream: cachelib.StreamCache,
    start: Array,              # (B,) context length before the chunk
    accepted: Array,           # (B,) tokens of the chunk to commit (>= 1)
    active: Array | None = None,
    *,
    perm: Array | None = None,
    phys_shards: int = 1,
):
    """Commit the accepted prefix of a verified chunk into the serve
    caches via the ragged chunk appends (PR 5) — the same scatter +
    incremental tau min/max merge a sequence of single-token appends
    performs, so committed state is bitwise what sequential decode leaves
    behind. Returns (paged', stream')."""
    h2 = spec.h2
    nr = spec.n_retrieval
    if perm is None:
        perm = identity_perm(spec)
    kp = _permute_kv(k_new, perm)
    vp = _permute_kv(v_new, perm)
    b = k_new.shape[0]
    act = jnp.ones((b,), bool) if active is None else \
        jnp.asarray(active).reshape(b)
    if nr > 0:
        paged = cachelib.paged_cache_append_chunk(
            paged, kp[:, :, :nr], vp[:, :, :nr], start, accepted,
            active=act, phys_shards=phys_shards)
    if spec.n_streaming > 0:
        stream = cachelib.stream_cache_append_chunk(
            stream, kp[:, :, nr:], vp[:, :, nr:], start, accepted,
            sink=h2.sink, active=act)
    return paged, stream


# ---------------------------------------------------------------------------
# Fixed-pool decode with eviction (paper §IV-A.3 "memory consideration")
# ---------------------------------------------------------------------------


def decode_attention_pool(
    spec: AttnSpec,
    q, k_new, v_new,
    paged: cachelib.PagedCache,
    stream: cachelib.StreamCache,
    length,
    *,
    do_select: bool,
    perm=None,
):
    """Decode against a FIXED-SIZE page pool (capacity = kv_budget tokens):
    when the pool is full, the lowest-accumulated-importance page is
    overwritten (sink/local pages protected). Slots are arbitrary — sink
    and local pages are found by their stored start positions.
    """
    h2 = spec.h2
    g = spec.group
    nr = spec.n_retrieval
    if perm is None:
        perm = identity_perm(spec)
    qp = _permute_q(q, perm, g)
    kp = _permute_kv(k_new, perm)
    vp = _permute_kv(v_new, perm)
    q_r, q_s = qp[:, : nr * g], qp[:, nr * g:]
    ctx = length + 1
    p_sz = h2.page_size

    outs = []
    if nr > 0:
        paged = cachelib.pool_append(
            paged, kp[:, :nr], vp[:, :nr], length,
            page=p_sz, sink=h2.sink, local=h2.local)
        if do_select:
            scores = paging.score_pages(
                q_r, paged.tau_min, paged.tau_max, paged.page_start, ctx,
                sink=h2.sink, local=h2.local, page=p_sz, impl=spec.impl)
            sel = paging.select_pages(scores, h2.top_k_pages)
            paged = dataclasses.replace(
                paged, sel_idx=sel,
                importance=paging.accumulate_importance(
                    paged.importance, scores))
        # sink/local slots by position lookup (pool slots are arbitrary)
        n_sink, n_local = paging.page_counts(sink=h2.sink, local=h2.local,
                                             page=p_sz)
        first_local = jnp.maximum(ctx - h2.local, 0) // p_sz
        sink_pos = jnp.arange(n_sink, dtype=jnp.int32) * p_sz
        local_pos = (first_local + jnp.arange(n_local, dtype=jnp.int32)) * p_sz
        sink_slots = paging.slots_of_positions(paged.page_start, sink_pos)
        local_slots = paging.slots_of_positions(paged.page_start, local_pos)
        slots = jnp.concatenate([sink_slots, paged.sel_idx, local_slots],
                                axis=2)
        gk, gv = paging.gather_pages(paged.k_pages, paged.v_pages, slots)
        valid = paging.token_validity(
            slots, paged.page_start, ctx, sink=h2.sink, local=h2.local,
            page=p_sz, top_k=h2.top_k_pages)
        outs.append(kops.paged_attention(q_r, gk, gv, valid, impl=spec.impl))
    if spec.n_streaming > 0:
        stream = cachelib.stream_cache_append(
            stream, kp[:, nr:], vp[:, nr:], length, sink=h2.sink)
        valid_s = (stream.pos >= 0) & (
            (stream.pos < h2.sink) | (stream.pos >= ctx - h2.local))
        outs.append(kops.paged_attention(
            q_s, stream.k, stream.v, valid_s, impl=spec.impl))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    out = _permute_q(out, _inverse_perm(perm), g)
    return out, paged, stream


# ---------------------------------------------------------------------------
# Distributed memory-compute co-placement (paper §IV-B via shard_map)
# ---------------------------------------------------------------------------
#
# The paged KV cache is sharded across the 'model' axis on the PAGE dim
# with interleaved (round-robin) page->shard assignment (Fig 7b). Each
# device appends/score/attends ONLY the pages it stores (compute moves to
# the data), producing flash partials (m, l, o); the cross-bank softmax is
# an exact (pmax, psum, psum) combine — the paper's FlashAttention-style
# cross-bank communication, at (2+D) floats per head instead of whole
# pages.


def _paged_decode_coplace(spec: AttnSpec, q_r, k_r, v_r,
                          paged: cachelib.PagedCache, length, *,
                          do_select: bool, mesh, axis: str = "model",
                          active=None, need_select=None):
    """Retrieval-head decode under interleaved co-placement.

    q_r: (B, HqR, D); k_r/v_r: (B, Hr, D) — replicated over `axis`.
    paged leaves sharded on the page dim over `axis` (page dim divisible).
    Returns (out (B,HqR,D), new PagedCache).

    Ragged (continuous-batching) path: ``length`` is (B,) per-slot,
    ``active`` masks live slots (retired slots neither append nor refresh —
    their local cache rows are bit-stable on every shard), ``need_select``
    is the per-slot share-window phase mask for the select variant. The
    per-slot vectors shard with the batch axis, so each device sees exactly
    the slots whose pages it co-owns.

    ``spec.impl`` selects the per-shard partial-attention body
    (kernels/ops.py): "ref" lowers the pure-jnp oracle and merges with a
    (pmax, psum, psum) collective; "pallas" runs the Pallas
    paged_attention_partial kernel per shard and merges with the fused
    combine_partials epilogue after an all_gather of the (2+D)-floats-
    per-head partials (the paper's cross-bank communication volume).
    Both are exact up to float reassociation; per-slot validity masking
    is identical (see docs/kernels.md).
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    h2 = spec.h2
    p_sz = h2.page_size
    cap_pages = paged.k_pages.shape[2]
    nsh = int(mesh.shape[axis])
    assert cap_pages % nsh == 0, (
        f"page capacity {cap_pages} must divide by {axis}={nsh}; "
        "round ServeConfig.capacity up to page_size*mesh_model pages")
    c_loc = cap_pages // nsh
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    b = q_r.shape[0]
    dp = int(np.prod([mesh.shape[a] for a in ba]))
    bspec = ba if b % dp == 0 else None
    ragged = active is not None or jnp.asarray(length).ndim == 1
    # static (trace-time) impl switch: selects the shard_map body's partial
    # kernel AND its combine strategy, never a per-step branch
    use_pallas = kops.resolve_impl(spec.impl) == "pallas"

    rep = P(bspec, None, None)
    cache5 = P(bspec, None, axis, None, None)
    cache4 = P(bspec, None, axis, None)
    cache3 = P(bspec, None, axis)
    vec = P(bspec)

    extra_args = ()
    extra_specs = ()
    if ragged:
        length = jnp.broadcast_to(
            jnp.asarray(length, jnp.int32), (b,))
        act = (jnp.ones((b,), bool) if active is None
               else jnp.asarray(active).reshape(b))
        extra_args = (act,)
        extra_specs = (vec,)
        if do_select:
            need = (jnp.ones((b,), bool) if need_select is None
                    else jnp.asarray(need_select).reshape(b))
            extra_args += (need,)
            extra_specs += (vec,)

    def body(q, kn, vn, kp, vp, tmin, tmax, imp, pstart, sel_prev, length,
             *extra):
        i = jax.lax.axis_index(axis)
        ctx = length + 1                       # scalar or (B_loc,)
        act = extra[0] if ragged else None
        need = extra[1] if (ragged and do_select) else None
        # ---- append (only the owner shard writes; retired slots masked) --
        kp, vp, tmin, tmax, pstart = cachelib.sharded_paged_append(
            kp, vp, tmin, tmax, pstart, kn, vn, length, page=p_sz,
            shard_idx=i, n_shards=nsh, active=act)

        # ---- selection (local score + distributed top-k) ----
        if do_select:
            scores_loc = paging.score_pages(
                q, tmin, tmax, pstart, ctx, sink=h2.sink, local=h2.local,
                page=p_sz, impl=spec.impl)          # (B, Hr, C_loc)
            imp_new = paging.accumulate_importance(imp, scores_loc)
            k_eff = min(h2.top_k_pages, c_loc)
            v_loc, i_loc = jax.lax.top_k(scores_loc, k_eff)
            phys_loc = i_loc + i * c_loc
            v_all = jax.lax.all_gather(v_loc, axis)   # (nsh, B, Hr, k)
            i_all = jax.lax.all_gather(phys_loc, axis)
            bsz, hr = v_loc.shape[0], v_loc.shape[1]
            v_cat = v_all.transpose(1, 2, 0, 3).reshape(bsz, hr, nsh * k_eff)
            i_cat = i_all.transpose(1, 2, 0, 3).reshape(bsz, hr, nsh * k_eff)
            sel_v, sel_pos = jax.lax.top_k(v_cat, min(h2.top_k_pages,
                                                      nsh * k_eff))
            sel = jnp.take_along_axis(i_cat, sel_pos, axis=2)
            sel = jnp.where(sel_v > NEG_INF_HALF, sel, -1)
            if sel.shape[2] < h2.top_k_pages:
                pad = jnp.full(sel.shape[:2] + (h2.top_k_pages
                                                - sel.shape[2],), -1,
                               jnp.int32)
                sel = jnp.concatenate([sel.astype(jnp.int32), pad], axis=2)
            sel = sel.astype(jnp.int32)
            if need is not None:
                # per-slot share window: slots whose window has not expired
                # keep their cached selection / importance bit-unchanged
                ns = need[:, None, None]
                sel = jnp.where(ns, sel, sel_prev)
                imp = jnp.where(ns, imp_new, imp)
            else:
                imp = imp_new
        else:
            sel = sel_prev

        # ---- attended slots (physical) + local partial attention ----
        slots_phys = paging.coplace_attended_slots(
            sel, ctx, sink=h2.sink, local=h2.local, page=p_sz,
            capacity=cap_pages, n_shards=nsh)
        loc = slots_phys - i * c_loc
        mine_s = (slots_phys >= 0) & (loc >= 0) & (loc < c_loc)
        loc_masked = jnp.where(mine_s, loc, -1)
        gk, gv = paging.gather_pages(kp, vp, loc_masked)
        valid = paging.token_validity(
            loc_masked, pstart, ctx, sink=h2.sink, local=h2.local,
            page=p_sz, top_k=h2.top_k_pages)
        m, l, o = kops.paged_attention_partial(q, gk, gv, valid,
                                               impl=spec.impl)

        # ---- cross-shard flash combine (the paper's cross-bank softmax) --
        if use_pallas:
            # fused epilogue: ship each shard's (2+D) floats per head and
            # run the max/rescale/sum/divide merge as one kernel
            m_all = jax.lax.all_gather(m, axis)      # (nsh, B, HqR)
            l_all = jax.lax.all_gather(l, axis)
            o_all = jax.lax.all_gather(o, axis)      # (nsh, B, HqR, D)
            out = kops.combine_partials(m_all, l_all, o_all,
                                        impl=spec.impl).astype(q.dtype)
        else:
            m_max = jax.lax.pmax(m, axis)
            corr = jnp.where(jnp.isfinite(m),
                             jnp.exp(m - jnp.where(jnp.isfinite(m_max),
                                                   m_max, 0.0)), 0.0)
            l_g = jax.lax.psum(l * corr, axis)
            o_g = jax.lax.psum(o * corr[..., None].astype(o.dtype), axis)
            out = (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)
        return out, kp, vp, tmin, tmax, imp, pstart, sel

    from repro.runtime.compat import shard_map as _shard_map

    len_spec = vec if ragged else P()
    shard = _shard_map(
        body, mesh=mesh,
        in_specs=(rep, rep, rep, cache5, cache5, cache4, cache4, cache3,
                  cache3, P(bspec, None, None), len_spec) + extra_specs,
        out_specs=(rep, cache5, cache5, cache4, cache4, cache3, cache3,
                   P(bspec, None, None)),
        check=False,
    )
    out, kpn, vpn, tminn, tmaxn, impn, pstartn, seln = shard(
        q_r, k_r, v_r, paged.k_pages, paged.v_pages, paged.tau_min,
        paged.tau_max, paged.importance, paged.page_start, paged.sel_idx,
        length, *extra_args)
    new_paged = cachelib.PagedCache(
        k_pages=kpn, v_pages=vpn, tau_min=tminn, tau_max=tmaxn,
        importance=impn, page_start=pstartn, sel_idx=seln)
    return out, new_paged


NEG_INF_HALF = -5e29


def decode_attention_coplace(spec: AttnSpec, q, k_new, v_new, paged, stream,
                             length, *, do_select: bool, perm=None,
                             axis: str = "model", active=None,
                             need_select=None):
    """decode_attention with the retrieval heads under shard_map
    co-placement. Streaming heads use the normal (tiny) path.

    Accepts the same ragged-batch arguments as ``decode_attention``
    (per-slot (B,) ``length``, ``active``, ``need_select``) — this is the
    path the continuous-batching engine takes under
    ``layout="coplace_shmap"``.
    """
    from repro.runtime import hints

    mesh = hints.current_mesh()
    if mesh is None:
        return decode_attention(spec, q, k_new, v_new, paged, stream,
                                length, do_select=do_select, perm=perm,
                                active=active, need_select=need_select)
    h2 = spec.h2
    g = spec.group
    nr = spec.n_retrieval
    if perm is None:
        perm = identity_perm(spec)
    qp = _permute_q(q, perm, g)
    kp = _permute_kv(k_new, perm)
    vp = _permute_kv(v_new, perm)
    ctx = length + 1
    outs = []
    if nr > 0:
        out_r, paged = _paged_decode_coplace(
            spec, qp[:, : nr * g], kp[:, :nr], vp[:, :nr], paged, length,
            do_select=do_select, mesh=mesh, axis=axis, active=active,
            need_select=need_select)
        outs.append(out_r)
    if spec.n_streaming > 0:
        stream = cachelib.stream_cache_append(
            stream, kp[:, nr:], vp[:, nr:], length, sink=h2.sink,
            active=active)
        ctx_b = jnp.broadcast_to(jnp.asarray(ctx, jnp.int32),
                                 (q.shape[0],))[:, None, None]
        valid_s = (stream.pos >= 0) & (
            (stream.pos < h2.sink) | (stream.pos >= ctx_b - h2.local))
        outs.append(kops.paged_attention(
            qp[:, nr * g:], stream.k, stream.v, valid_s, impl=spec.impl))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    out = _permute_q(out, _inverse_perm(perm), g)
    return out, paged, stream


# ---------------------------------------------------------------------------
# Full-attention baseline (paper's "full attention" HB baseline)
# ---------------------------------------------------------------------------


def full_decode_attention(spec: AttnSpec, q, k_new, v_new,
                          cache: cachelib.FullCache, length,
                          active: Array | None = None):
    cache = cachelib.full_cache_append(cache, k_new, v_new, length,
                                       active=active)
    b = q.shape[0]
    lb = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))[:, None, None]
    pos = jnp.arange(cache.k.shape[2])[None, None, :]
    valid = pos < (lb + 1)
    if spec.window > 0:
        valid &= pos > (lb - spec.window)
    valid = jnp.broadcast_to(valid, cache.k.shape[:3])
    out = kops.paged_attention(q, cache.k, cache.v, valid, impl=spec.impl)
    return out, cache
