"""Head identification (paper §IV-A.1, following DuoAttention).

During identification training, every head's output is a convex mix of
full attention and streaming attention gated by a trainable α ∈ [0,1]
(the ONLY trainable parameter). An L1 penalty pushes α toward 0; heads
whose α stays high are retrieval heads.

    Attn_{i,j} = α_{i,j} · Full_Attn + (1 − α_{i,j}) · Streaming_Attn
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

Array = jax.Array


def init_alpha(num_layers: int, n_kv: int) -> Array:
    """α initialised to 1 (paper: 'At beginning, α's are initialized to 1')."""
    return jnp.ones((num_layers, n_kv), jnp.float32)


def clip_alpha(alpha: Array) -> Array:
    return jnp.clip(alpha, 0.0, 1.0)


def gated_attention(q, k, v, alpha_layer, *, sink: int, local: int,
                    impl: str = "ref"):
    """q: (B,S,Hq,D); k/v: (B,S,Hkv,D); alpha_layer: (Hkv,).

    Returns the α-gated mix of full and streaming attention per kv head
    (broadcast over the GQA group).
    """
    b, s, hq, d = q.shape
    h_kv = k.shape[2]
    group = hq // h_kv
    full = kops.flash_attention(q, k, v, causal=True, impl=impl)
    stream = kops.flash_attention(q, k, v, causal=True, window=local,
                                  sink=sink, impl=impl)
    a = jnp.repeat(clip_alpha(alpha_layer), group)  # (Hq,)
    a = a[None, None, :, None]
    return a * full + (1.0 - a) * stream


def gating_loss(task_loss: Array, alpha: Array, lam: float = 0.05) -> Array:
    """task_loss + λ·‖α‖₁ (drives unnecessary heads toward streaming)."""
    return task_loss + lam * jnp.sum(jnp.abs(alpha))


def classify_heads(alpha: Array, static_sparsity: float):
    """Per layer: permutation putting retrieval heads first.

    The number of retrieval heads per layer is fixed by ``static_sparsity``
    (paper §V-B sets the *proportion* of streaming heads globally); which
    heads are retrieval is decided by the per-layer α ranking.

    Returns perms (num_layers, Hkv) int32: layer l's kv-head order.
    """
    num_layers, h_kv = alpha.shape
    n_stream = round(h_kv * static_sparsity)
    n_ret = h_kv - n_stream
    order = jnp.argsort(-alpha, axis=1)  # descending α: retrieval first
    del n_ret
    return order.astype(jnp.int32)
