"""KV-cache structures for H²EAL serving.

Three cache kinds:

  FullCache    — dense (B, H, S, D) baseline (paper's "full attention" HB
                 baseline; also used when ``h2eal.enabled = False``).
  PagedCache   — retrieval heads: paged KV + Quest min/max metadata +
                 accumulated importance (+ page_start table so a fixed-size
                 pool with eviction is expressible with static shapes).
  StreamCache  — streaming heads: sink + local ring buffer only (this is
                 where the paper's memory reduction comes from).

All are registered pytree dataclasses so they can live inside jitted
functions and be sharded leaf-wise.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _dc(cls):
    fields = [f.name for f in dataclasses.fields(cls)]
    return jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])


@_dc
@dataclasses.dataclass
class FullCache:
    k: Array  # (B, Hkv, S, D)
    v: Array  # (B, Hkv, S, D)


@_dc
@dataclasses.dataclass
class PagedCache:
    k_pages: Array     # (B, Hr, C, P, D)
    v_pages: Array     # (B, Hr, C, P, D)
    tau_min: Array     # (B, Hr, C, D)   elementwise min of keys in page
    tau_max: Array     # (B, Hr, C, D)
    importance: Array  # (B, Hr, C)      accumulated relevance (f32)
    page_start: Array  # (B, Hr, C)      absolute pos of first token; -1 empty
    sel_idx: Array     # (B, Hr, K)      cached top-k selection (shared window)


@_dc
@dataclasses.dataclass
class StreamCache:
    k: Array  # (B, Hs, W, D)  W = sink + local_cap, local part is a ring
    v: Array  # (B, Hs, W, D)
    pos: Array  # (B, Hs, W)   absolute position stored in each slot; -1 empty


def empty_fill_value(path: str):
    """Empty-cache sentinel for a serve-state leaf identified by its
    pytree key path — the single source of truth the constructors above
    encode shape-wise (``make_paged_cache`` / ``make_stream_cache``):
    tau_min +inf, tau_max -inf, page_start and the stream ring's ``pos``
    -1, the xLSTM max-stabilizer ``m`` -inf (init_mlstm_state /
    init_slstm_state), everything else 0. Consumed by the serving
    engine's dynamic-slot reset (chunked admission) so a cleared slot
    row is exactly what a fresh constructor would produce."""
    if "tau_min" in path:
        return jnp.inf
    if "tau_max" in path:
        return -jnp.inf
    if "page_start" in path or path.endswith(".pos"):
        return -1
    if path.endswith("['m']"):
        return -jnp.inf
    return 0


def make_full_cache(b, h_kv, capacity, d, dtype=jnp.bfloat16):
    z = jnp.zeros((b, h_kv, capacity, d), dtype)
    return FullCache(k=z, v=z)


def make_paged_cache(b, h_r, num_pages, page, d, top_k, dtype=jnp.bfloat16):
    zp = jnp.zeros((b, h_r, num_pages, page, d), dtype)
    return PagedCache(
        k_pages=zp,
        v_pages=zp,
        tau_min=jnp.full((b, h_r, num_pages, d), jnp.inf, jnp.float32),
        tau_max=jnp.full((b, h_r, num_pages, d), -jnp.inf, jnp.float32),
        importance=jnp.zeros((b, h_r, num_pages), jnp.float32),
        page_start=jnp.full((b, h_r, num_pages), -1, jnp.int32),
        sel_idx=jnp.zeros((b, h_r, top_k), jnp.int32),
    )


def make_stream_cache(b, h_s, sink, local_cap, d, dtype=jnp.bfloat16):
    w = sink + local_cap
    z = jnp.zeros((b, h_s, w, d), dtype)
    return StreamCache(k=z, v=z, pos=jnp.full((b, h_s, w), -1, jnp.int32))


# ---------------------------------------------------------------------------
# Append ops (decode: one token for all heads of one layer)
#
# ``length`` is a scalar on the uniform (lockstep) path and a (B,) vector
# on the continuous-batching ragged path, where each slot writes at its own
# position. ``active`` ((B,) bool, ragged path only) masks retired / empty
# slots: their rows are written back unchanged, so a slot's cache is
# bit-stable while it waits for the next admission.
# ---------------------------------------------------------------------------


def _is_ragged(length, active) -> bool:
    return active is not None or jnp.asarray(length).ndim == 1


def _row_mask(active, b: int) -> Array:
    if active is None:
        return jnp.ones((b,), bool)
    return jnp.asarray(active).reshape(b)


def full_cache_append(cache: FullCache, k_new: Array, v_new: Array, length,
                      active=None):
    """k_new/v_new: (B, Hkv, D); length: scalar or (B,) context len."""
    if not _is_ragged(length, active):
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new[:, :, None, :].astype(cache.k.dtype),
            (0, 0, length, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new[:, :, None, :].astype(cache.v.dtype),
            (0, 0, length, 0))
        return FullCache(k=k, v=v)
    b, h, s, _ = cache.k.shape
    lb = jnp.clip(jnp.broadcast_to(length, (b,)), 0, s - 1)
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(h)[None, :]
    sl = jnp.broadcast_to(lb[:, None], (b, h))
    act = _row_mask(active, b)[:, None, None]
    k_wr = jnp.where(act, k_new.astype(cache.k.dtype), cache.k[bi, hi, sl])
    v_wr = jnp.where(act, v_new.astype(cache.v.dtype), cache.v[bi, hi, sl])
    return FullCache(k=cache.k.at[bi, hi, sl].set(k_wr),
                     v=cache.v.at[bi, hi, sl].set(v_wr))


def stream_cache_append(cache: StreamCache, k_new, v_new, length, *,
                        sink: int, active=None):
    """Ring-buffer append: pos<sink go to slot=pos, else ring over local part."""
    w = cache.k.shape[2]
    local_cap = w - sink
    if not _is_ragged(length, active):
        slot = jnp.where(length < sink, length,
                         sink + (length - sink) % local_cap)
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new[:, :, None, :].astype(cache.k.dtype), (0, 0, slot, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new[:, :, None, :].astype(cache.v.dtype), (0, 0, slot, 0))
        pos = jax.lax.dynamic_update_slice(
            cache.pos, jnp.broadcast_to(length, cache.pos.shape[:2])[:, :, None].astype(jnp.int32),
            (0, 0, slot))
        return StreamCache(k=k, v=v, pos=pos)
    b, h, _, _ = cache.k.shape
    lb = jnp.broadcast_to(length, (b,)).astype(jnp.int32)
    slot = jnp.where(lb < sink, lb, sink + (lb - sink) % local_cap)
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(h)[None, :]
    sl = jnp.broadcast_to(slot[:, None], (b, h))
    act = _row_mask(active, b)
    k_wr = jnp.where(act[:, None, None], k_new.astype(cache.k.dtype),
                     cache.k[bi, hi, sl])
    v_wr = jnp.where(act[:, None, None], v_new.astype(cache.v.dtype),
                     cache.v[bi, hi, sl])
    pos_wr = jnp.where(act[:, None], lb[:, None], cache.pos[bi, hi, sl])
    return StreamCache(k=cache.k.at[bi, hi, sl].set(k_wr),
                       v=cache.v.at[bi, hi, sl].set(v_wr),
                       pos=cache.pos.at[bi, hi, sl].set(
                           pos_wr.astype(jnp.int32)))


def paged_cache_append(cache: PagedCache, k_new, v_new, length, active=None):
    """Append one token at absolute position ``length`` (page = length//P).

    Metadata for the page is updated incrementally (running min/max).
    No-eviction layout: page index is position//P (capacity covers max ctx).
    """
    p = cache.k_pages.shape[3]
    if not _is_ragged(length, active):
        page = length // p
        off = length % p
        k_pages = jax.lax.dynamic_update_slice(
            cache.k_pages, k_new[:, :, None, None, :].astype(cache.k_pages.dtype),
            (0, 0, page, off, 0))
        v_pages = jax.lax.dynamic_update_slice(
            cache.v_pages, v_new[:, :, None, None, :].astype(cache.v_pages.dtype),
            (0, 0, page, off, 0))
        kf = k_new.astype(jnp.float32)[:, :, None, :]
        old_min = jax.lax.dynamic_slice(
            cache.tau_min, (0, 0, page, 0),
            (cache.tau_min.shape[0], cache.tau_min.shape[1], 1, cache.tau_min.shape[3]))
        old_max = jax.lax.dynamic_slice(
            cache.tau_max, (0, 0, page, 0),
            (cache.tau_max.shape[0], cache.tau_max.shape[1], 1, cache.tau_max.shape[3]))
        tau_min = jax.lax.dynamic_update_slice(
            cache.tau_min, jnp.minimum(old_min, kf), (0, 0, page, 0))
        tau_max = jax.lax.dynamic_update_slice(
            cache.tau_max, jnp.maximum(old_max, kf), (0, 0, page, 0))
        start = jax.lax.dynamic_update_slice(
            cache.page_start,
            jnp.broadcast_to(page * p, cache.page_start.shape[:2])[:, :, None].astype(jnp.int32),
            (0, 0, page))
        return dataclasses.replace(
            cache, k_pages=k_pages, v_pages=v_pages,
            tau_min=tau_min, tau_max=tau_max, page_start=start)

    b, h, c, _, _ = cache.k_pages.shape
    lb = jnp.broadcast_to(length, (b,)).astype(jnp.int32)
    page = jnp.clip(lb // p, 0, c - 1)
    off = lb % p
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(h)[None, :]
    pg = jnp.broadcast_to(page[:, None], (b, h))
    of = jnp.broadcast_to(off[:, None], (b, h))
    act = _row_mask(active, b)
    a3 = act[:, None, None]
    k_wr = jnp.where(a3, k_new.astype(cache.k_pages.dtype),
                     cache.k_pages[bi, hi, pg, of])
    v_wr = jnp.where(a3, v_new.astype(cache.v_pages.dtype),
                     cache.v_pages[bi, hi, pg, of])
    kf = k_new.astype(jnp.float32)
    old_min = cache.tau_min[bi, hi, pg]
    old_max = cache.tau_max[bi, hi, pg]
    min_wr = jnp.where(a3, jnp.minimum(old_min, kf), old_min)
    max_wr = jnp.where(a3, jnp.maximum(old_max, kf), old_max)
    start_wr = jnp.where(act[:, None], jnp.broadcast_to((page * p)[:, None],
                                                        (b, h)),
                         cache.page_start[bi, hi, pg])
    return dataclasses.replace(
        cache,
        k_pages=cache.k_pages.at[bi, hi, pg, of].set(k_wr),
        v_pages=cache.v_pages.at[bi, hi, pg, of].set(v_wr),
        tau_min=cache.tau_min.at[bi, hi, pg].set(min_wr),
        tau_max=cache.tau_max.at[bi, hi, pg].set(max_wr),
        page_start=cache.page_start.at[bi, hi, pg].set(
            start_wr.astype(jnp.int32)))


def sharded_paged_append(k_pages, v_pages, tau_min, tau_max, page_start,
                         k_new, v_new, length, *, page: int, shard_idx,
                         n_shards: int, active=None):
    """Owner-shard append for the co-placed (shard_map) paged layout.

    The leaves hold this shard's ``C_loc = C / n_shards`` pages of the
    interleaved physical layout (paper Fig 7b: logical page ``p`` lives on
    shard ``p % n_shards``). Only the shard that owns the token's page
    writes; every other shard returns its leaves bit-unchanged, so the
    global cache state is exactly the unsharded one, page-permuted.

    ``length`` is a scalar (lockstep) or (B,) per-slot vector (continuous
    batching); ``active`` masks retired slots on the ragged path the same
    way as ``paged_cache_append``. Returns the five updated leaves.
    """
    from repro.core import paging

    c_loc = k_pages.shape[2]
    cap = c_loc * n_shards
    if not _is_ragged(length, active):
        pg = length // page
        off = length % page
        phys = paging.interleave_slot(pg, cap, n_shards)
        local = phys - shard_idx * c_loc
        mine = (local >= 0) & (local < c_loc)
        lc = jnp.clip(local, 0, c_loc - 1)
        kp2 = jax.lax.dynamic_update_slice(
            k_pages, k_new[:, :, None, None, :].astype(k_pages.dtype),
            (0, 0, lc, off, 0))
        vp2 = jax.lax.dynamic_update_slice(
            v_pages, v_new[:, :, None, None, :].astype(v_pages.dtype),
            (0, 0, lc, off, 0))
        kf = k_new.astype(jnp.float32)[:, :, None, :]
        sl = lambda a: jax.lax.dynamic_slice(
            a, (0, 0, lc, 0), (a.shape[0], a.shape[1], 1, a.shape[3]))
        tmin2 = jax.lax.dynamic_update_slice(
            tau_min, jnp.minimum(sl(tau_min), kf), (0, 0, lc, 0))
        tmax2 = jax.lax.dynamic_update_slice(
            tau_max, jnp.maximum(sl(tau_max), kf), (0, 0, lc, 0))
        ps2 = jax.lax.dynamic_update_slice(
            page_start,
            jnp.broadcast_to(pg * page, page_start.shape[:2])[
                :, :, None].astype(jnp.int32),
            (0, 0, lc))
        return (jnp.where(mine, kp2, k_pages), jnp.where(mine, vp2, v_pages),
                jnp.where(mine, tmin2, tau_min),
                jnp.where(mine, tmax2, tau_max),
                jnp.where(mine, ps2, page_start))

    b, h = k_new.shape[0], k_pages.shape[1]
    lb = jnp.broadcast_to(length, (b,)).astype(jnp.int32)
    pg = jnp.clip(lb // page, 0, cap - 1)
    off = lb % page
    phys = paging.interleave_slot(pg, cap, n_shards)
    local = phys - shard_idx * c_loc
    mine = (local >= 0) & (local < c_loc)
    lc = jnp.clip(local, 0, c_loc - 1)
    act = _row_mask(active, b) & mine
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(h)[None, :]
    pgl = jnp.broadcast_to(lc[:, None], (b, h))
    of = jnp.broadcast_to(off[:, None], (b, h))
    a3 = act[:, None, None]
    k_wr = jnp.where(a3, k_new.astype(k_pages.dtype),
                     k_pages[bi, hi, pgl, of])
    v_wr = jnp.where(a3, v_new.astype(v_pages.dtype),
                     v_pages[bi, hi, pgl, of])
    kf = k_new.astype(jnp.float32)
    old_min = tau_min[bi, hi, pgl]
    old_max = tau_max[bi, hi, pgl]
    min_wr = jnp.where(a3, jnp.minimum(old_min, kf), old_min)
    max_wr = jnp.where(a3, jnp.maximum(old_max, kf), old_max)
    start_wr = jnp.where(act[:, None],
                         jnp.broadcast_to((pg * page)[:, None], (b, h)),
                         page_start[bi, hi, pgl])
    return (k_pages.at[bi, hi, pgl, of].set(k_wr),
            v_pages.at[bi, hi, pgl, of].set(v_wr),
            tau_min.at[bi, hi, pgl].set(min_wr),
            tau_max.at[bi, hi, pgl].set(max_wr),
            page_start.at[bi, hi, pgl].set(start_wr.astype(jnp.int32)))


def _ext_overflow(a: Array) -> Array:
    """Append one transient overflow slot on axis 2 (the page / sequence
    dim). Chunk appends route masked-out tokens there so no valid write
    ever aliases a masked one (scatter with duplicate indices and
    different values is undefined); callers slice the slot away with
    ``[:, :, :n]`` after the scatter — the stream_cache_from_prefill
    trick."""
    pad = [(0, 0)] * a.ndim
    pad[2] = (0, 1)
    return jnp.pad(a, pad)


def paged_cache_append_chunk(cache: PagedCache, k_new, v_new, start,
                             chunk_len, *, active=None, phys_shards: int = 1):
    """Multi-token ragged chunk append (chunked prefill).

    k_new/v_new: (B, C, Hr, D) — per-slot prompt chunks, left-aligned.
    Slot ``b`` appends its first ``chunk_len[b]`` tokens at absolute
    positions ``start[b] .. start[b]+chunk_len[b]-1``; the rest of the
    chunk (and every row with ``active`` False) appends nothing. Page
    min/max metadata merges via scatter-min/max — exact for chunks that
    open, fill, or straddle pages, PROVIDED the touched pages start from
    the empty sentinels (the engine resets a slot's rows at admission).

    ``phys_shards`` > 1 routes each logical page through
    ``paging.interleave_slot`` (the coplace_shmap physical round-robin
    striping); the metadata keeps absolute positions, so validity math
    is layout-independent. Invalid tokens scatter into a transient
    overflow page that is sliced away (the stream_cache_from_prefill
    trick), so no valid write ever aliases a masked one.
    """
    from repro.core import paging

    b, cch, h, d = k_new.shape
    cap = cache.k_pages.shape[2]
    p_sz = cache.k_pages.shape[3]
    start = jnp.broadcast_to(start, (b,)).astype(jnp.int32)
    clen = jnp.broadcast_to(chunk_len, (b,)).astype(jnp.int32)
    act = _row_mask(active, b)
    j = jnp.arange(cch, dtype=jnp.int32)
    pos = start[:, None] + j[None, :]                       # (B, C)
    valid = (j[None, :] < clen[:, None]) & act[:, None]
    page_log = jnp.clip(pos // p_sz, 0, cap - 1)
    phys = paging.interleave_slot(page_log, cap, phys_shards)
    off = pos % p_sz
    pg_eff = jnp.where(valid, phys, cap)                    # cap = overflow
    ext = _ext_overflow
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(h)[None, :, None]
    pg = pg_eff[:, None, :]
    of = off[:, None, :]
    kt = k_new.transpose(0, 2, 1, 3)                        # (B, H, C, D)
    vt = v_new.transpose(0, 2, 1, 3)
    k_pages = ext(cache.k_pages).at[bi, hi, pg, of].set(
        kt.astype(cache.k_pages.dtype))[:, :, :cap]
    v_pages = ext(cache.v_pages).at[bi, hi, pg, of].set(
        vt.astype(cache.v_pages.dtype))[:, :, :cap]
    kf = kt.astype(jnp.float32)
    tau_min = ext(cache.tau_min).at[bi, hi, pg].min(kf)[:, :, :cap]
    tau_max = ext(cache.tau_max).at[bi, hi, pg].max(kf)[:, :, :cap]
    ps_val = jnp.broadcast_to((page_log * p_sz)[:, None, :], (b, h, cch))
    page_start = ext(cache.page_start).at[bi, hi, pg].set(
        ps_val.astype(jnp.int32))[:, :, :cap]
    return dataclasses.replace(
        cache, k_pages=k_pages, v_pages=v_pages, tau_min=tau_min,
        tau_max=tau_max, page_start=page_start)


def stream_cache_append_chunk(cache: StreamCache, k_new, v_new, start,
                              chunk_len, *, sink: int, active=None):
    """Chunk append into the sink+local ring (chunked prefill).

    k_new/v_new: (B, C, Hs, D). Equivalent to appending the chunk's
    tokens one at a time with ``stream_cache_append`` — expressed in
    closed form: each ring slot keeps the LAST appended position mapping
    to it (later positions win, matching ring semantics), so a chunk
    larger than the ring is handled exactly.
    """
    b, cch, h, d = k_new.shape
    w = cache.k.shape[2]
    local_cap = w - sink
    start = jnp.broadcast_to(start, (b,)).astype(jnp.int32)
    clen = jnp.broadcast_to(chunk_len, (b,)).astype(jnp.int32)
    act = _row_mask(active, b)
    e = start + clen - 1                                    # last position
    wi = jnp.arange(w, dtype=jnp.int32)
    # the position written LAST into each slot: sink slots hold their own
    # index; ring slot w holds the largest appended p >= sink with
    # (p - sink) % local_cap == w - sink
    r = wi[None, :] - sink
    m = (e[:, None] - sink - r) % local_cap                 # (B, W) >= 0
    p_ring = e[:, None] - m
    p_tgt = jnp.where(wi[None, :] < sink, wi[None, :], p_ring)
    written = (act[:, None] & (p_tgt >= start[:, None])
               & (p_tgt <= e[:, None])
               & ((wi[None, :] < sink) | (p_tgt >= sink)))
    jidx = jnp.clip(p_tgt - start[:, None], 0, cch - 1)     # chunk offset
    kt = k_new.transpose(0, 2, 1, 3)                        # (B, H, C, D)
    vt = v_new.transpose(0, 2, 1, 3)
    take = lambda a: jnp.take_along_axis(
        a, jnp.broadcast_to(jidx[:, None, :, None], (b, h, w, 1)), axis=2)
    wr = written[:, None, :, None]
    k2 = jnp.where(wr, take(kt).astype(cache.k.dtype), cache.k)
    v2 = jnp.where(wr, take(vt).astype(cache.v.dtype), cache.v)
    pos2 = jnp.where(written[:, None, :],
                     jnp.broadcast_to(p_tgt[:, None, :], (b, h, w)),
                     cache.pos)
    return StreamCache(k=k2, v=v2, pos=pos2.astype(jnp.int32))


def full_cache_append_chunk(cache: FullCache, k_new, v_new, start,
                            chunk_len, active=None):
    """Chunk append for the dense baseline cache (chunked prefill of
    full-attention / plain-window layers). k_new/v_new: (B, C, Hkv, D)
    appended at positions ``start .. start+chunk_len-1`` per slot."""
    b, cch, h, d = k_new.shape
    s = cache.k.shape[2]
    start = jnp.broadcast_to(start, (b,)).astype(jnp.int32)
    clen = jnp.broadcast_to(chunk_len, (b,)).astype(jnp.int32)
    act = _row_mask(active, b)
    j = jnp.arange(cch, dtype=jnp.int32)
    pos = start[:, None] + j[None, :]
    valid = (j[None, :] < clen[:, None]) & act[:, None]
    sl_eff = jnp.where(valid, jnp.clip(pos, 0, s - 1), s)   # s = overflow
    ext = _ext_overflow
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(h)[None, :, None]
    sl = sl_eff[:, None, :]
    kt = k_new.transpose(0, 2, 1, 3)
    vt = v_new.transpose(0, 2, 1, 3)
    return FullCache(
        k=ext(cache.k).at[bi, hi, sl].set(kt.astype(cache.k.dtype))[:, :, :s],
        v=ext(cache.v).at[bi, hi, sl].set(vt.astype(cache.v.dtype))[:, :, :s])


def pool_append(cache: PagedCache, k_new: Array, v_new: Array, length: Array,
                *, page: int, sink: int, local: int):
    """Fixed-pool append with eviction (paper §IV-A.3 'memory
    consideration'): the pool holds ``C_pool`` pages; when a NEW page opens
    and the pool is full, the live page with the LOWEST accumulated
    importance is overwritten. Sink and local-window pages are protected.

    k_new/v_new: (B, Hr, D); length: scalar. Slots are per-(B, H) (each
    head evicts independently, as in the paper).
    """
    b, h, c_pool, p_sz, d = cache.k_pages.shape
    pg = length // page
    off = length % page
    pos0 = (pg * page).astype(jnp.int32)

    # slot of the page currently open at pos0 (if any)
    is_open = cache.page_start == pos0                      # (B,H,C)
    open_slot = jnp.argmax(is_open, axis=-1).astype(jnp.int32)
    has_open = jnp.any(is_open, axis=-1)

    # eviction candidate: dead slots first, else lowest importance among
    # unprotected live pages (sink pages and the local window never evict)
    dead = cache.page_start < 0
    local_lo = jnp.maximum(length + 1 - local, 0)
    protected = (cache.page_start < sink) | (cache.page_start >= (local_lo // page) * page)
    protected &= ~dead
    evict_score = jnp.where(dead, -jnp.inf,
                            jnp.where(protected, jnp.inf, cache.importance))
    evict_slot = jnp.argmin(evict_score, axis=-1).astype(jnp.int32)

    slot = jnp.where(has_open, open_slot, evict_slot)       # (B,H)
    fresh = ~has_open                                       # opening a page

    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(h)[None, :]
    kf = k_new.astype(jnp.float32)

    k_pages = cache.k_pages.at[bi, hi, slot, off].set(
        k_new.astype(cache.k_pages.dtype))
    v_pages = cache.v_pages.at[bi, hi, slot, off].set(
        v_new.astype(cache.v_pages.dtype))
    old_min = jnp.where(fresh[..., None], jnp.inf,
                        cache.tau_min[bi, hi, slot])
    old_max = jnp.where(fresh[..., None], -jnp.inf,
                        cache.tau_max[bi, hi, slot])
    tau_min = cache.tau_min.at[bi, hi, slot].set(jnp.minimum(old_min, kf))
    tau_max = cache.tau_max.at[bi, hi, slot].set(jnp.maximum(old_max, kf))
    imp = jnp.where(fresh, 0.0, cache.importance[bi, hi, slot])
    importance = cache.importance.at[bi, hi, slot].set(imp)
    page_start = cache.page_start.at[bi, hi, slot].set(
        jnp.broadcast_to(pos0, (b, h)))
    return dataclasses.replace(
        cache, k_pages=k_pages, v_pages=v_pages, tau_min=tau_min,
        tau_max=tau_max, importance=importance, page_start=page_start)


# ---------------------------------------------------------------------------
# Tiered hot/cold page residency (two-tier KV cache)
#
# The paged caches' k/v page rows are the only leaves that move between
# tiers: selection scores, page validity, and the append bookkeeping all
# read the metadata leaves (tau_min/tau_max/importance/page_start), which
# stay device-resident, so a spilled page is *selectable* (and its
# selection is bit-identical to the all-resident cache) even while its
# contents live in the far store. The serving engine detects
# selected-but-cold pages after the (metadata-only) selection, fills
# them, and replays the step — served late, never skipped.
#
# The three tree ops below are generic over a batched serve-state pytree:
# they path-match leaves whose key ends in ``.k_pages`` / ``.v_pages``
# and use the engine's leaf convention (batch axis 1 for scan-stacked
# "blocks" leaves, else 0; the page axis is two to the right of batch).
# Page-index vectors are fixed-length (the cache's page count) and
# -1-padded; padded entries are routed to a transient overflow row that
# is sliced away (the ``_ext_overflow`` trick), so each op is one compile
# regardless of how many pages move.
# ---------------------------------------------------------------------------


def _is_kv_page_leaf(ps: str) -> bool:
    return ps.endswith(".k_pages") or ps.endswith(".v_pages")


def _leaf_batch_axis(ps: str) -> int:
    return 1 if "['blocks']" in ps else 0


def gather_kv_page_rows(state, slot):
    """Read slot ``slot``'s k/v page rows out of the batched serve state.

    Returns ``{path: (C, ...)}`` — one stacked array per paged k/v leaf,
    page axis moved to the front. The engine device_gets this to archive
    pages into the far store before zeroing them on device.
    """
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        ps = jax.tree_util.keystr(path)
        if not _is_kv_page_leaf(ps):
            continue
        ax = _leaf_batch_axis(ps)
        row = jax.lax.dynamic_index_in_dim(leaf, slot, axis=ax,
                                           keepdims=False)
        out[ps] = jnp.moveaxis(row, ax + 1, 0)
    return out


def _update_kv_page_rows(state, slot, pages, value_fn):
    """Scatter into slot ``slot``'s page rows at physical page indices
    ``pages`` ((C,) int32, -1 padded). ``value_fn(path, ext, idx)``
    writes into the page-fronted, overflow-extended view ``ext``
    ((C+1, ...)); padded indices land on the overflow row, which is
    sliced away. Non-k/v leaves pass through untouched."""

    def upd(path, leaf):
        ps = jax.tree_util.keystr(path)
        if not _is_kv_page_leaf(ps):
            return leaf
        ax = _leaf_batch_axis(ps)
        row = jax.lax.dynamic_index_in_dim(leaf, slot, axis=ax,
                                           keepdims=False)
        moved = jnp.moveaxis(row, ax + 1, 0)                # (C, ...)
        c = moved.shape[0]
        ext = jnp.concatenate(
            [moved, jnp.zeros((1,) + moved.shape[1:], moved.dtype)], axis=0)
        idx = jnp.where(pages >= 0, pages, c).astype(jnp.int32)
        ext = value_fn(ps, ext, idx)
        row2 = jnp.moveaxis(ext[:c], 0, ax + 1)
        row2 = jnp.expand_dims(row2, ax)
        start = (0,) * ax + (slot,) + (0,) * (leaf.ndim - ax - 1)
        return jax.lax.dynamic_update_slice(leaf, row2.astype(leaf.dtype),
                                            start)

    return jax.tree_util.tree_map_with_path(upd, state)


def spill_kv_page_rows(state, slot, pages):
    """Zero the k/v contents of ``pages`` for slot ``slot`` (the cold
    tier's device-side residue — zero is the empty-page sentinel, so a
    spilled page is indistinguishable from an empty one to the kernels;
    only the untouched metadata says otherwise)."""
    return _update_kv_page_rows(
        state, slot, pages, lambda ps, ext, idx: ext.at[idx].set(0))


def fill_kv_page_rows(state, slot, pages, rows):
    """Restore far-store rows into ``pages`` of slot ``slot``. ``rows``
    is ``{path: (C, ...)}`` aligned with ``pages`` entry-wise (padding
    entries carry zeros and land on the discarded overflow row). Exact
    inverse of spill: the page contents return bit-identical."""
    return _update_kv_page_rows(
        state, slot, pages,
        lambda ps, ext, idx: ext.at[idx].set(rows[ps].astype(ext.dtype)))


# -- batched (slot, page)-pair variants (PR 10) -----------------------------
#
# One refresh plan touches many slots; the per-slot ops above would cost
# one dispatch per slot per direction. These variants take fixed-length
# -1-padded (M,) slot/page index vectors — M is the engine's static pair
# capacity (n_slots x n_pages), so ONE compiled program applies any
# refresh plan as one batched gather plus one batched scatter per
# direction. Same overflow-row trick, lifted to the flattened
# (batch x page) row space; (slot, page) pairs are unique by
# construction, so the scatters never collide.


def _pair_flat(leaf, ps: str):
    """Leaf -> ((B*C, ...) pair-row view, the (B, C, ...) shape, ax)."""
    ax = _leaf_batch_axis(ps)
    m = jnp.moveaxis(leaf, ax, 0)          # batch to front
    m = jnp.moveaxis(m, ax + 2, 1)         # page axis rides at ax+2
    return m.reshape((-1,) + m.shape[2:]), m.shape, ax


def _pair_idx(slots, pages, c: int, n: int):
    """Flattened pair-row indices; padded (-1) pairs -> overflow row n."""
    fi = slots.astype(jnp.int32) * c + pages.astype(jnp.int32)
    return jnp.where((slots >= 0) & (pages >= 0), fi, n)


def gather_kv_rows_pairs(state, slots, pages):
    """Batched ``gather_kv_page_rows``: read M (slot, page) page rows out
    of the batched serve state in one program. Returns
    ``{path: (M, ...)}``; padded pairs return zeros."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        ps = jax.tree_util.keystr(path)
        if not _is_kv_page_leaf(ps):
            continue
        flat, mshape, _ = _pair_flat(leaf, ps)
        n, c = flat.shape[0], mshape[1]
        ext = jnp.concatenate(
            [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)], axis=0)
        out[ps] = ext[_pair_idx(slots, pages, c, n)]
    return out


def _update_kv_rows_pairs(state, slots, pages, value_fn):
    def upd(path, leaf):
        ps = jax.tree_util.keystr(path)
        if not _is_kv_page_leaf(ps):
            return leaf
        flat, mshape, ax = _pair_flat(leaf, ps)
        n, c = flat.shape[0], mshape[1]
        ext = jnp.concatenate(
            [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)], axis=0)
        ext = value_fn(ps, ext, _pair_idx(slots, pages, c, n))
        m2 = ext[:n].reshape(mshape)
        m2 = jnp.moveaxis(m2, 1, ax + 2)
        return jnp.moveaxis(m2, 0, ax).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(upd, state)


def spill_kv_rows_pairs(state, slots, pages):
    """Batched ``spill_kv_page_rows``: zero M (slot, page) page rows in
    one program (zero is the empty-page sentinel)."""
    return _update_kv_rows_pairs(
        state, slots, pages, lambda ps, ext, idx: ext.at[idx].set(0))


def fill_kv_rows_pairs(state, slots, pages, rows):
    """Batched ``fill_kv_page_rows``: restore ``{path: (M, ...)}``
    far-store rows into M (slot, page) page rows in one program. Exact
    inverse of the batched spill."""
    return _update_kv_rows_pairs(
        state, slots, pages,
        lambda ps, ext, idx: ext.at[idx].set(rows[ps].astype(ext.dtype)))


class TieredPagedCache:
    """Host-side residency controller for the two-tier paged KV cache.

    Tracks, per engine slot, which **physical** pages are device-resident
    (``resident`` bitmap) and archives spilled page rows in a host far
    store (``far``) keyed ``(slot, phys_page) -> {path: np row}`` — the
    simulated HB far bank (hbsim/sim.py costs the traffic). The policy
    methods are pure bookkeeping over numpy; the device-side spill/fill
    tree ops live next to it in this module and are dispatched by the
    serving engine.

    Residency policy (exactness-safe by construction):

    * **Pinned (never spilled):** sink pages, every page at or above the
      local-window start ``first_local(ctx)`` (local span + the current
      append page + not-yet-written pages), and the currently selected
      pages. Since ``first_local`` only grows with context, a page below
      it is complete and will never be appended to or re-enter the local
      window — the *only* way a spilled page is read again is via
      selection, which is metadata-only and therefore miss-detectable.
    * **Hot set:** pinned pages plus the ``hot_pages`` - |pinned| most
      important spill candidates (the accumulated Quest hotness the
      selector maintains). ``hot_pages`` is a soft per-slot budget: pins
      may exceed it.
    * **Refresh:** at each selection refresh the engine asks
      ``plan_refresh`` for pages to prefetch (``to_fill`` — hot again
      but cold on device; fetched one share window ahead of the next
      selection) and pages to spill (``to_spill``).

    Physical vs logical: ``stripe_shards`` > 1 applies the coplace_shmap
    round-robin page striping (core/paging.interleave_slot); selection
    indices and importance are already physical there, so the bitmap and
    far store are kept in physical page space and only the sink/local
    pins are mapped through the stripe.
    """

    def __init__(self, *, n_slots: int, n_pages: int, hot_pages: int,
                 page_size: int, sink: int, local: int,
                 stripe_shards: int = 1):
        from repro.core import paging

        self.n_slots = int(n_slots)
        self.n_pages = int(n_pages)
        self.hot_pages = int(hot_pages)
        self.page_size = int(page_size)
        self.sink = int(sink)
        self.local = int(local)
        self.stripe = max(int(stripe_shards), 1)
        self.n_sink_pages, _ = paging.page_counts(
            sink=sink, local=local, page=page_size)
        self.resident = np.ones((self.n_slots, self.n_pages), bool)
        self.far: dict = {}   # (slot, phys_page) -> {path: np row}

    # -- page-space mapping -------------------------------------------
    def phys(self, logical: int) -> int:
        from repro.core import paging

        if self.stripe == 1:
            return int(logical)
        return int(paging.interleave_slot(logical, self.n_pages,
                                          self.stripe))

    def first_local(self, ctx: int) -> int:
        return max(int(ctx) - self.local, 0) // self.page_size

    def data_pages(self, ctx: int) -> int:
        return -(-int(ctx) // self.page_size)

    # -- residency bookkeeping ----------------------------------------
    def reset_slot(self, slot: int):
        """Slot retired or (re)admitted: the next occupant's pack/reset
        overwrites every device row, so the whole slot is resident."""
        self.resident[slot] = True
        for key in [k for k in self.far if k[0] == slot]:
            del self.far[key]

    def missing(self, slot: int, pages) -> list:
        """Subset of physical ``pages`` not device-resident (the cold
        misses of a fresh selection)."""
        return [p for p in pages if not self.resident[slot, p]]

    def store_rows(self, slot: int, pages, rows: dict):
        """Archive gathered page rows (``{path: (C, ...)}``) into the far
        store. Idempotent per page: a page already archived keeps its
        copy (complete pages never change on device, so the copy stays
        exact across spill/fill/spill cycles)."""
        for p in pages:
            if (slot, p) in self.far:
                continue
            self.far[(slot, p)] = {ps: np.asarray(buf[p]).copy()
                                   for ps, buf in rows.items()}

    def store_pair_rows(self, slots, pages, rows: dict, count: int):
        """Archive a batched pair gather (``{path: (M, ...)}`` aligned
        with the (slot, page) index vectors; first ``count`` entries
        real). Same idempotence rule as ``store_rows``."""
        for i in range(count):
            key = (int(slots[i]), int(pages[i]))
            if key in self.far:
                continue
            self.far[key] = {ps: np.asarray(buf[i]).copy()
                             for ps, buf in rows.items()}

    # -- policy --------------------------------------------------------
    def spill_candidates(self, slot: int, ctx: int, selected) -> list:
        """Physical pages legal to spill: complete pages strictly between
        the sink and local sections, minus ``selected``."""
        fl = self.first_local(ctx)
        return [self.phys(p) for p in range(self.n_sink_pages, fl)
                if self.phys(p) not in selected]

    def plan_refresh(self, slot: int, ctx: int, selected, hotness):
        """(to_fill, to_spill) physical page lists for one refresh.

        ``selected`` — the slot's fresh physical selection (already
        resident: misses were repaired before this runs); ``hotness`` —
        (n_pages,) accumulated importance in physical page space. The
        want-set is pins ∪ top-m candidates by hotness, m sized so the
        resident data pages meet the ``hot_pages`` budget."""
        fl = self.first_local(ctx)
        nd = self.data_pages(ctx)
        cand = self.spill_candidates(slot, ctx, selected)
        pinned_data = (min(self.n_sink_pages, nd) + max(nd - fl, 0)
                       + len(selected))
        m = max(self.hot_pages - pinned_data, 0)
        order = sorted(cand, key=lambda p: (-float(hotness[p]), p))
        want = set(order[:m])
        to_fill = [p for p in order[:m] if not self.resident[slot, p]]
        to_spill = [p for p in cand
                    if p not in want and self.resident[slot, p]]
        return to_fill, to_spill


# ---------------------------------------------------------------------------
# Prefill constructors (build caches from full-sequence K/V)
# ---------------------------------------------------------------------------


def paged_cache_from_prefill(k, v, num_pages, page, top_k):
    """k/v: (B, S, Hr, D) -> PagedCache with S//P pages filled (S % P == 0)."""
    b, s, h, d = k.shape
    n_filled = s // page
    kp = k.transpose(0, 2, 1, 3).reshape(b, h, n_filled, page, d)
    vp = v.transpose(0, 2, 1, 3).reshape(b, h, n_filled, page, d)
    pad = num_pages - n_filled
    kf = kp.astype(jnp.float32)
    tau_min = jnp.pad(kf.min(axis=3), ((0, 0), (0, 0), (0, pad), (0, 0)),
                      constant_values=jnp.inf)
    tau_max = jnp.pad(kf.max(axis=3), ((0, 0), (0, 0), (0, pad), (0, 0)),
                      constant_values=-jnp.inf)
    z = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
    start = jnp.arange(num_pages, dtype=jnp.int32) * page
    start = jnp.where(jnp.arange(num_pages) < n_filled, start, -1)
    return PagedCache(
        k_pages=jnp.pad(kp, z), v_pages=jnp.pad(vp, z),
        tau_min=tau_min, tau_max=tau_max,
        importance=jnp.zeros((b, h, num_pages), jnp.float32),
        page_start=jnp.broadcast_to(start, (b, h, num_pages)).astype(jnp.int32),
        sel_idx=jnp.zeros((b, h, top_k), jnp.int32),
    )


def stream_cache_from_prefill(k, v, *, sink, local_cap, length):
    """k/v: (B, S, Hs, D); keep sink + last min(local_cap, S-sink) tokens.

    ``length`` is the static int prefill length (== S).
    """
    b, s, h, d = k.shape
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    w = sink + local_cap
    cache = make_stream_cache(b, h, sink, local_cap, d, dtype=k.dtype)
    # positions that belong in the ring and their slots
    pos = jnp.arange(s)
    slot = jnp.where(pos < sink, pos, sink + (pos - sink) % local_cap)
    keep = (pos < sink) | (pos >= max(sink, length - local_cap))
    # scatter (later positions win, matching ring semantics) — iterate via
    # segment trick: sort by (keep, pos) then scatter
    slot_eff = jnp.where(keep, slot, w)  # dump discarded into overflow slot
    kk = jnp.zeros((b, h, w + 1, d), k.dtype).at[:, :, slot_eff].set(k)
    vv = jnp.zeros((b, h, w + 1, d), v.dtype).at[:, :, slot_eff].set(v)
    pp = jnp.full((b, h, w + 1), -1, jnp.int32).at[:, :, slot_eff].set(
        jnp.broadcast_to(pos, (b, h, s)).astype(jnp.int32))
    return StreamCache(k=kk[:, :, :w], v=vv[:, :, :w], pos=pp[:, :, :w])
