"""Page selection for retrieval heads (paper §IV-A.3).

Two-step pipeline: (1) relevance score of every page from its min/max
metadata, (2) top-k page selection. Selection is shared across
``share_window`` consecutive queries (LServe).

Consistent page partition (ctx = current context length; in the paper,
tokens enter pages only when they pop out of the local FIFO, so pages and
the local window never overlap — we express the same invariant with the
position->page layout by anchoring the local section at the page boundary
below ctx-local):

  first_local = max((ctx - local) // P, 0)
  sink section:     pages [0, n_sink): ALL in-context tokens (a superset of
                    the configured sink count, rounded up to page boundary)
  local section:    pages [first_local, first_local + n_local) with
                    n_local = ceil(local/P)+1; tokens valid iff
                    pos >= max(first_local, n_sink) * P
  selected section: top-k over pages in [n_sink, first_local)

Sink and local windows are therefore elastic by up to P-1 *extra* tokens
(never fewer than configured — retrieval heads attend a superset; streaming
heads use the exact sink/local counts). The three sections are mutually
exclusive and their union covers every resident token when top-k spans all
selectable pages — nothing is ever dropped at section boundaries or
double-counted in the softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

Array = jax.Array
NEG_INF = -1e30


def page_counts(*, sink: int, local: int, page: int) -> tuple[int, int]:
    """(n_sink_pages, n_local_pages) — static page counts always attended."""
    n_sink = -(-sink // page) if sink else 0
    n_local = -(-local // page) + 1 if local else 0  # +1 boundary page
    return n_sink, n_local


def _ctx_batched(ctx: Array, b: int) -> Array:
    """Normalize ctx to per-batch-row shape (B,).

    ``ctx`` is a scalar for the uniform (lockstep) decode path and a (B,)
    vector for the continuous-batching engine's ragged path; downstream
    math broadcasts over (B, H, ...) identically for both.
    """
    return jnp.broadcast_to(jnp.asarray(ctx, jnp.int32), (b,))


def _first_local_page(ctx: Array, *, local: int, page: int) -> Array:
    return jnp.maximum(ctx - local, 0) // page


def score_pages(
    q: Array,
    tau_min: Array,
    tau_max: Array,
    page_start: Array,
    ctx: Array,
    *,
    sink: int,
    local: int,
    page: int,
    impl: str = "ref",
) -> Array:
    """Relevance scores (B, Hkv, C); sink/local/empty pages forced to -inf.

    ``ctx`` may be a scalar (uniform batch) or (B,) (ragged batch).
    """
    scores = kops.page_score(q, tau_min, tau_max, impl=impl)
    n_sink, _ = page_counts(sink=sink, local=local, page=page)
    ctx = _ctx_batched(ctx, page_start.shape[0])
    first_local = _first_local_page(ctx, local=local, page=page)[:, None, None]
    pidx = jnp.where(page_start >= 0, page_start // page, -1)
    selectable = (page_start >= 0) & (pidx >= n_sink) & (pidx < first_local)
    return jnp.where(selectable, scores, NEG_INF)


def select_pages(scores: Array, top_k: int) -> Array:
    """Top-k page slots per (B, Hkv): (B, Hkv, K) int32.

    If fewer than ``top_k`` pages exist, the selection is padded with -1
    sentinels (masked downstream).
    """
    k_eff = min(top_k, scores.shape[-1])
    _, idx = jax.lax.top_k(scores, k_eff)
    idx = idx.astype(jnp.int32)
    if k_eff < top_k:
        pad = jnp.full(idx.shape[:-1] + (top_k - k_eff,), -1, jnp.int32)
        idx = jnp.concatenate([idx, pad], axis=-1)
    return idx


def attended_page_slots(
    sel_idx: Array,
    ctx: Array,
    *,
    sink: int,
    local: int,
    page: int,
) -> Array:
    """Concatenate [sink pages | selected pages | local pages] slot indices.

    Returns (B, Hkv, n_sink + K + n_local) int32. Assumes the no-eviction
    layout where slot == page index == position // page. Out-of-range local
    slots are clamped for gather safety; token_validity() masks them.
    ``ctx`` may be a scalar (uniform batch) or (B,) (ragged batch).
    """
    b, h, _ = sel_idx.shape
    n_sink, n_local = page_counts(sink=sink, local=local, page=page)
    sink_pages = jnp.broadcast_to(
        jnp.arange(n_sink, dtype=jnp.int32), (b, h, n_sink))
    ctx = _ctx_batched(ctx, b)
    first_local = _first_local_page(ctx, local=local, page=page)[:, None, None]
    local_pages = first_local + jnp.arange(n_local, dtype=jnp.int32)
    local_pages = jnp.maximum(local_pages, 0)
    local_pages = jnp.broadcast_to(local_pages, (b, h, n_local)).astype(jnp.int32)
    return jnp.concatenate([sink_pages, sel_idx, local_pages], axis=2)


def coplace_attended_slots(
    sel_phys: Array,
    ctx: Array,
    *,
    sink: int,
    local: int,
    page: int,
    capacity: int,
    n_shards: int,
) -> Array:
    """`attended_page_slots` for the co-placed (shard_map) layout.

    ``sel_phys`` (B, H, K) holds PHYSICAL slot indices (the distributed
    top-k already returns physical ids; -1 = sentinel). The fixed sink and
    local sections are logical page indices mapped through
    ``interleave_slot``. ``capacity`` is the GLOBAL page count; each shard
    later subtracts its base offset and masks slots it does not own.
    ``ctx`` may be a scalar (lockstep) or (B,) (ragged batch).

    Logical local pages past the end of the cache are clamped to the last
    page — the same page the unsharded path's clamped gather reads — and
    `token_validity` masks them, so sharded and unsharded attend the same
    token set.
    """
    b, h, _ = sel_phys.shape
    n_sink, n_local = page_counts(sink=sink, local=local, page=page)
    ctx = _ctx_batched(ctx, b)
    first_local = _first_local_page(ctx, local=local, page=page)  # (B,)
    sink_log = jnp.broadcast_to(jnp.arange(n_sink, dtype=jnp.int32),
                                (b, n_sink))
    local_log = first_local[:, None] + jnp.arange(n_local, dtype=jnp.int32)
    fixed_log = jnp.concatenate([sink_log, local_log], axis=1)
    fixed_log = jnp.clip(fixed_log, 0, capacity - 1)
    fixed_phys = interleave_slot(fixed_log, capacity, n_shards)
    fixed_phys = jnp.broadcast_to(
        fixed_phys[:, None, :], (b, h, n_sink + n_local)).astype(jnp.int32)
    return jnp.concatenate(
        [fixed_phys[:, :, :n_sink], sel_phys.astype(jnp.int32),
         fixed_phys[:, :, n_sink:]], axis=2)


def gather_pages(k_pages: Array, v_pages: Array, slots: Array):
    """k/v_pages: (B, H, C, P, D); slots: (B, H, N) -> (B, H, N*P, D) each."""
    b, h, c, p, d = k_pages.shape
    n = slots.shape[2]
    sc = jnp.maximum(slots, 0)[:, :, :, None, None]
    k = jnp.take_along_axis(k_pages, sc, axis=2)
    v = jnp.take_along_axis(v_pages, sc, axis=2)
    return k.reshape(b, h, n * p, d), v.reshape(b, h, n * p, d)


def token_validity(
    slots: Array,
    page_start: Array,
    ctx: Array,
    *,
    sink: int,
    local: int,
    page: int,
    top_k: int,
) -> Array:
    """Validity mask (B, H, N*P) for the gathered token buffer.

    Enforces the section partition documented in the module docstring, so
    the three sections never overlap even for degenerate selections (short
    contexts where nothing is selectable yet).
    ``ctx`` may be a scalar (uniform batch) or (B,) (ragged batch).

    Sharding-safe: under the co-placed layout ``slots`` are shard-LOCAL
    slot indices (non-owned slots masked to -1) while ``page_start`` stores
    ABSOLUTE token positions, so the section math (pidx, first_local) stays
    in global coordinates and is identical on every shard.
    """
    b, h, n = slots.shape
    n_sink, n_local = page_counts(sink=sink, local=local, page=page)
    sentinel = (slots < 0)[:, :, :, None]
    start = jnp.take_along_axis(page_start, jnp.maximum(slots, 0), axis=2)
    offs = jnp.arange(page, dtype=jnp.int32)
    pos = start[:, :, :, None] + offs[None, None, None, :]  # (B,H,N,P)
    nonempty = (start >= 0)[:, :, :, None]
    ctx = _ctx_batched(ctx, b)
    in_ctx = pos < ctx[:, None, None, None]
    section = jnp.concatenate([
        jnp.zeros((n_sink,), jnp.int32),
        jnp.ones((top_k,), jnp.int32),
        jnp.full((n_local,), 2, jnp.int32),
    ])
    sec = section[None, None, :, None]
    first_local = _first_local_page(ctx, local=local, page=page)[:, None, None]
    pidx = start // page
    ok_sink = jnp.broadcast_to(True, pos.shape)  # whole sink page(s)
    ok_local = (
        (pos >= (jnp.maximum(first_local, n_sink) * page)[:, :, :, None])
        & (pidx >= first_local)[:, :, :, None]
    )
    ok_sel = ((pidx >= n_sink) & (pidx < first_local))[:, :, :, None]
    ok = jnp.where(sec == 0, ok_sink, jnp.where(sec == 2, ok_local, ok_sel))
    return (nonempty & in_ctx & ok & ~sentinel).reshape(b, h, n * page)


# ---------------------------------------------------------------------------
# Chunked prefill (multi-token) validity
#
# During chunked prefill there is no page selection: retrieval heads
# attend FULL causal (exactly like single-shot prefill), streaming heads
# sink+local. Keys live in cache buffers whose layout is physical (pages
# may be slot-permuted, the stream ring wraps), so validity is computed
# from absolute POSITIONS, never from slot indices — identical math on
# every layout.
# ---------------------------------------------------------------------------


def chunk_positions(start: Array, chunk: int) -> Array:
    """Absolute positions (B, C) of a left-aligned chunk starting at
    ``start`` (B,). Rows are valid only below the caller's chunk_len."""
    start = jnp.asarray(start, jnp.int32).reshape(-1)
    return start[:, None] + jnp.arange(chunk, dtype=jnp.int32)[None, :]


def paged_key_positions(page_start: Array, page: int):
    """(key_pos, key_ok) for the flattened page buffer.

    page_start: (B, H, C) absolute first-token positions (-1 = empty).
    Returns key_pos (B, H, C*P) int32 and key_ok (B, H, C*P) bool. Works
    for any physical page order (the metadata carries absolute
    positions).
    """
    b, h, c = page_start.shape
    offs = jnp.arange(page, dtype=jnp.int32)
    pos = page_start[:, :, :, None] + offs[None, None, None, :]
    ok = jnp.broadcast_to((page_start >= 0)[:, :, :, None], pos.shape)
    return pos.reshape(b, h, c * page), ok.reshape(b, h, c * page)


def chunk_causal_validity(key_pos: Array, key_ok: Array,
                          pos_q: Array) -> Array:
    """Causal chunk-prefill mask: (B, H, Cq, T) — key attended iff it
    exists and its position is <= the query's. key_pos/key_ok: (B, H, T);
    pos_q: (B, Cq). Appended-but-unwritten page offsets are excluded by
    the causal bound alone (their positions are >= every chunk query)."""
    return (key_ok[:, :, None, :]
            & (key_pos[:, :, None, :] <= pos_q[:, None, :, None]))


def chunk_stream_validity(key_pos: Array, pos_q: Array, *, sink: int,
                          local: int) -> Array:
    """Sink+local chunk-prefill mask, matching the streaming decode mask
    ((pos < sink) | (pos > q - local)) and the flash window semantics.
    key_pos: (B, H, T) with -1 = empty slot; pos_q: (B, Cq).
    Returns (B, H, Cq, T)."""
    kp = key_pos[:, :, None, :]
    pq = pos_q[:, None, :, None]
    return (key_pos >= 0)[:, :, None, :] & (kp <= pq) & (
        (kp < sink) | (kp > pq - local))


# ---------------------------------------------------------------------------
# Speculative verify (multi-query decode over the PRE-APPEND cache)
#
# The verify chunk holds k tokens at positions start .. start+k-1 (start =
# current context length); query j plays the role of decode step j and must
# attend EXACTLY what the sequential engine's token j would attend. Keys at
# positions >= start are not in the cache yet (attend-before-append) — they
# arrive as the causally-masked chunk tail, so the paged buffer only ever
# supplies positions < start and the per-page in-context bound is the CACHE
# context, shared by all queries. What IS per-query is the section
# partition: first_local_j = first_local(start+j+1) grows with j, so a page
# can be local for query 0 and selectable-but-unselected (hence dropped,
# exactly as the sequential reuse step drops it) for query k-1.
#
# The gathered buffer is anchored at first_local(start+1): every query's
# local low edge is >= it, and the highest live page (start-1)//page is
# within n_local pages of it, so no extension is needed — per-query
# validity does all the sectioning.
# ---------------------------------------------------------------------------


def verify_attended_slots(
    sel_idx: Array,
    ctx: Array,
    *,
    sink: int,
    local: int,
    page: int,
    capacity: int,
    n_shards: int = 1,
) -> Array:
    """[sink | selected | local] slot indices for the verify gather.

    ``ctx`` is start+1 (B,) — the context of the FIRST verify query, which
    anchors the shared local section. ``sel_idx`` (B, Hkv, K) holds slot
    indices in the cache's physical page order (identical to logical order
    unless ``n_shards > 1``); the fixed sink/local sections are logical
    page indices mapped through ``interleave_slot`` (identity for 1 shard)
    and clipped for gather safety — verify_token_validity masks the
    clipped duplicates. Returns (B, Hkv, n_sink + K + n_local) int32.
    """
    b, h, _ = sel_idx.shape
    n_sink, n_local = page_counts(sink=sink, local=local, page=page)
    ctx = _ctx_batched(ctx, b)
    first_local = _first_local_page(ctx, local=local, page=page)  # (B,)
    sink_log = jnp.broadcast_to(jnp.arange(n_sink, dtype=jnp.int32),
                                (b, n_sink))
    local_log = first_local[:, None] + jnp.arange(n_local, dtype=jnp.int32)
    fixed_log = jnp.concatenate([sink_log, local_log], axis=1)
    fixed_log = jnp.clip(fixed_log, 0, capacity - 1)
    fixed_phys = interleave_slot(fixed_log, capacity, n_shards)
    fixed_phys = jnp.broadcast_to(
        fixed_phys[:, None, :], (b, h, n_sink + n_local)).astype(jnp.int32)
    return jnp.concatenate(
        [fixed_phys[:, :, :n_sink], sel_idx.astype(jnp.int32),
         fixed_phys[:, :, n_sink:]], axis=2)


def verify_token_validity(
    slots: Array,
    page_start: Array,
    cache_ctx: Array,
    pos_q: Array,
    *,
    sink: int,
    local: int,
    page: int,
    top_k: int,
) -> Array:
    """Per-query validity (B, H, Cq, N*P) for the gathered verify buffer.

    Same section rules as ``token_validity`` with two deltas: the
    in-context bound is the PRE-APPEND cache length ``cache_ctx`` (B,) —
    identical for every query because chunk-tail keys are supplied
    separately — and the sink/selected/local partition is evaluated at
    each query's own context ``pos_q + 1`` (pos_q: (B, Cq) absolute query
    positions), so section membership shifts across the chunk exactly as
    it does across k sequential decode steps.
    """
    b, h, n = slots.shape
    cq = pos_q.shape[1]
    n_sink, n_local = page_counts(sink=sink, local=local, page=page)
    sentinel = (slots < 0)[:, :, None, :, None]
    start = jnp.take_along_axis(page_start, jnp.maximum(slots, 0), axis=2)
    offs = jnp.arange(page, dtype=jnp.int32)
    pos = (start[:, :, :, None] + offs[None, None, None, :])[:, :, None]
    nonempty = (start >= 0)[:, :, None, :, None]
    cache_ctx = _ctx_batched(cache_ctx, b)
    in_ctx = pos < cache_ctx[:, None, None, None, None]
    section = jnp.concatenate([
        jnp.zeros((n_sink,), jnp.int32),
        jnp.ones((top_k,), jnp.int32),
        jnp.full((n_local,), 2, jnp.int32),
    ])
    sec = section[None, None, None, :, None]
    first_local = _first_local_page(
        pos_q + 1, local=local, page=page)[:, None, :, None, None]
    pidx = (start // page)[:, :, None, :, None]
    ok_sink = jnp.broadcast_to(True, pos.shape)
    ok_local = ((pos >= jnp.maximum(first_local, n_sink) * page)
                & (pidx >= first_local))
    ok_sel = (pidx >= n_sink) & (pidx < first_local)
    ok = jnp.where(sec == 0, ok_sink, jnp.where(sec == 2, ok_local, ok_sel))
    return (nonempty & in_ctx & ok & ~sentinel).reshape(b, h, cq, n * page)


def accumulate_importance(importance: Array, scores: Array) -> Array:
    """Paper: accumulate the computed relevance score at each step.

    Scores of masked pages are NEG_INF; those contribute 0.
    """
    return importance + jnp.where(scores > NEG_INF / 2, scores, 0.0)


def interleave_slot(page: Array, capacity: int, n_shards: int) -> Array:
    """Physical cache slot for logical page index under interleaved
    (round-robin) bank allocation (paper Fig 7b): owner shard = page mod
    n_shards, so any top-k selection lands uniformly on all shards.

    Identity when n_shards == 1. capacity must divide by n_shards.
    """
    if n_shards == 1:
        return page
    local_c = capacity // n_shards
    return (page % n_shards) * local_c + page // n_shards


def slots_of_positions(page_start: Array, positions: Array) -> Array:
    """Pool-mode slot lookup: for each target page-start position, the
    slot holding it (or -1). page_start: (B, H, C); positions: (N,) or
    (B, H, N) -> (B, H, N) int32."""
    if positions.ndim == 1:
        positions = jnp.broadcast_to(
            positions[None, None], page_start.shape[:2] + positions.shape)
    eq = page_start[:, :, :, None] == positions[:, :, None, :]
    slot = jnp.argmax(eq, axis=2).astype(jnp.int32)
    found = jnp.any(eq, axis=2)
    return jnp.where(found, slot, -1)


def evict_lowest(cache_importance: Array, page_start: Array):
    """Return per-(B,H) slot index of the lowest-importance *live* page.

    Used by the fixed-pool (kv_budget) mode: the returned slot is overwritten
    by the next page.
    """
    live = page_start >= 0
    masked = jnp.where(live, cache_importance, jnp.inf)
    return jnp.argmin(masked, axis=-1).astype(jnp.int32)
