"""AdamW with decoupled weight decay + global-norm clipping (pure pytrees)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    import copy
    return {"mu": zeros, "nu": jax.tree.map(jnp.zeros_like, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, gnorm


def cosine_schedule(step, *, base_lr_scale=1.0, warmup: int = 100,
                    total: int = 10_000, min_frac: float = 0.1):
    """Multiplier for cfg.lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr_scale * warm * cos
