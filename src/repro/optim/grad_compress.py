"""Gradient compression for the data-parallel all-reduce.

Two mechanisms:
  * bf16 gradients — halves all-reduce bytes; applied by casting grads
    before the (GSPMD-inserted) reduction. Safe default at scale.
  * int8 + error feedback — 4x compression; quantize(g + e) per leaf with
    a per-leaf scale, carry the quantization error e into the next step.
    Used with an explicit shard_map psum (runtime/train.py, optional) so
    the wire format is actually int8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def to_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                        params)


def quantize_int8(g: jax.Array):
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, errors):
    """Returns (quantized pytree of (q, scale), new_errors)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return (q, s), corrected - deq

    flat = jax.tree.map(one, grads, errors,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    qtree = jax.tree.map(lambda pair: pair[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    etree = jax.tree.map(lambda pair: pair[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return qtree, etree
