from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    init_state,
)
from repro.optim import grad_compress  # noqa: F401
