"""Deterministic, seekable synthetic data pipeline.

Every batch is a pure function of (seed, step) — a crashed run restarted
from a checkpoint at step N regenerates exactly the batches it would have
seen, with no data-loader state to persist. Shardable: the global batch is
generated whole and sharded by the caller's in_shardings (device layout
never changes the stream).

Two generators:
  lm_batch        — zipf-distributed token stream with local n-gram
                    structure (so a small model has something to learn).
  niah_batch      — Needle-in-a-Haystack: a (key, value) pair is planted at
                    a controlled depth inside filler; the model is queried
                    for the value at the end. Used by the accuracy
                    benchmarks (paper Fig 13 proxy).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def _keys(seed: int, step: int, n: int):
    k = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.split(k, n)


@partial(jax.jit, static_argnames=("batch", "seq", "vocab", "seed"))
def lm_batch(step: Array, *, batch: int, seq: int, vocab: int,
             seed: int = 0):
    """Returns {tokens (B,S) int32, labels (B,S) int32}.

    Structure: zipf-ish unigram draw mixed with a first-order recurrence
    (token_t depends on token_{t-1}) so cross-entropy is reducible.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    # zipf via inverse-cdf on uniform
    u = jax.random.uniform(k1, (batch, seq), minval=1e-6, maxval=1.0)
    base = (jnp.exp(-jnp.log(u) * 0.35) - 1.0)
    base = jnp.clip(base.astype(jnp.int32), 0, vocab - 1)
    # first-order structure: with p=0.5 token_t = f(token_{t-1})
    mix = jax.random.bernoulli(k2, 0.5, (batch, seq))
    shifted = jnp.roll(base, 1, axis=1)
    det = (shifted * 31 + 7) % vocab
    tokens = jnp.where(mix, det, base)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-100)
    return {"tokens": tokens, "labels": labels}


@partial(jax.jit,
         static_argnames=("batch", "seq", "vocab", "depth_frac", "seed"))
def niah_batch(step: Array, *, batch: int, seq: int, vocab: int,
               depth_frac: float = 0.5, seed: int = 0):
    """Needle-in-a-haystack probe batches.

    Layout per row:  [filler ... K V ... filler ... K] -> next token = V.
    K is drawn from a reserved key alphabet [vocab-64, vocab-32); V from
    [vocab-32, vocab). Returns tokens, the answer V (B,), and the needle
    position.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    filler = jax.random.randint(k1, (batch, seq), 0, max(vocab - 64, 1))
    kk = jax.random.randint(k2, (batch,), vocab - 64, vocab - 32)
    vv = jax.random.randint(k3, (batch,), vocab - 32, vocab)
    pos = int(seq * depth_frac)
    pos = min(max(pos, 0), seq - 3)
    tokens = filler.at[:, pos].set(kk).at[:, pos + 1].set(vv)
    tokens = tokens.at[:, -1].set(kk)  # query: repeat the key
    return {"tokens": tokens, "answer": vv, "needle_pos": pos}


def token_stream(*, batch: int, seq: int, vocab: int, seed: int = 0):
    """Infinite iterator over lm_batch steps (host-side convenience)."""
    step = 0
    while True:
        yield lm_batch(jnp.int32(step), batch=batch, seq=seq, vocab=vocab,
                       seed=seed)
        step += 1
