from repro.data.pipeline import lm_batch, niah_batch, token_stream  # noqa: F401
