"""Pallas TPU page-relevance scoring (Quest min/max metadata).

score(page) = Σ_{g in group} Σ_d max(q_gd · τmin_d, q_gd · τmax_d)
            = Σ_g [ relu(q_g)·τmax + min(q_g, 0)·τmin ]

(the per-coordinate max of a linear function over an interval sits at an
endpoint, picked by sign(q_d) — so the sum-of-maxes is exactly two MXU
matmuls with a sign-split q). The metadata tensors stream through VMEM in
(BC, D) tiles — the paper's memory-die min/max metadata units,
re-expressed for the MXU.

Layout: q (BH, G, D); tau (BH, C, D) -> scores (BH, C), BH = B * Hkv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, tmin_ref, tmax_ref, o_ref):
    q = q_ref[0].astype(jnp.float32)          # (G, D)
    tmin = tmin_ref[0].astype(jnp.float32)    # (BC, D)
    tmax = tmax_ref[0].astype(jnp.float32)    # (BC, D)
    qp = jnp.maximum(q, 0.0)
    qn = jnp.minimum(q, 0.0)
    hi = jnp.dot(tmax, qp.T, preferred_element_type=jnp.float32)  # (BC, G)
    lo = jnp.dot(tmin, qn.T, preferred_element_type=jnp.float32)
    o_ref[0] = (hi + lo).sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def page_score(q, tau_min, tau_max, *, bc=512, interpret=False):
    """q: (B, Hq, D); tau_min/max: (B, Hkv, C, D) -> (B, Hkv, C) f32."""
    b, hq, d = q.shape
    h_kv, c = tau_min.shape[1], tau_min.shape[2]
    g = hq // h_kv
    qg = q.reshape(b * h_kv, g, d)
    tn = tau_min.reshape(b * h_kv, c, d)
    tx = tau_max.reshape(b * h_kv, c, d)

    bc_ = min(bc, c)
    nc = pl.cdiv(c, bc_)
    out = pl.pallas_call(
        _kernel,
        grid=(b * h_kv, nc),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ci: (bh, 0, 0)),
            pl.BlockSpec((1, bc_, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, bc_, d), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc_), lambda bh, ci: (bh, ci)),
        out_shape=jax.ShapeDtypeStruct((b * h_kv, c), jnp.float32),
        interpret=interpret,
    )(qg, tn, tx)
    return out.reshape(b, h_kv, c)
