"""Pallas TPU chunked-prefill attention.

Two entry points (see docs/kernels.md for the full catalog):

  chunk_attention        — multi-query attention over a gathered KV buffer
                           with PER-QUERY validity: the (Cq, T) masks the
                           caller derives from absolute positions. The
                           whole chunk's queries stay resident in VMEM as
                           one (Cq*G, D) operand while KV streams past in
                           (BT, D) tiles — the Cq == 1 special case is
                           exactly paged_attention.
  chunk_attention_paged  — the same online-softmax stream with the page
                           gather FUSED into the kernel: instead of a
                           materialized buffer + (B, H, Cq, T) mask, the
                           grid walks (pages..., chunk) and validity is
                           computed in-kernel from page_start. Pre-append
                           cache keys need only per-KEY validity (every
                           buffered key precedes every chunk query), and
                           the intra-chunk phase needs only a STATIC
                           causal mask — no per-query mask ever hits HBM.

Both reuse the (m, l, acc) online-softmax contract of
paged_attention._stream_tile: init at the first tile, masked
rescale-and-accumulate per tile, normalize in the last tile's epilogue
(all-invalid rows yield 0 via the l = max(l, 1e-30) guard).

Layout: q is folded to (BH, Cq*G, D) with row r = c*G + g, BH = B*Hkv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _accumulate(s, ok, v, m_ref, l_ref, acc_ref):
    """One masked rescale-and-accumulate step of the online softmax.

    s: (R, T) logits already NEG_INF-masked; ok: bool broadcastable to
    (R, T); v: (T, D) f32. Updates the (m, l, acc) VMEM state in place.
    """
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)  # all-masked tile: exp(-inf - -inf) = 1
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _chunk_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, bt, seq_t, cq, group):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cols = ti * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    inb = cols < seq_t                                       # (BT, 1)
    k = jnp.where(inb, k_ref[0].astype(jnp.float32), 0.0)    # (BT, D)
    v = jnp.where(inb, v_ref[0].astype(jnp.float32), 0.0)
    # per-query tile mask, expanded over the GQA group: row r = c*G + g
    okq = (valid_ref[0] != 0) & inb[:, 0][None, :]           # (Cq, BT)
    ok = jnp.broadcast_to(okq[:, None, :], (cq, group, bt)).reshape(
        cq * group, bt)
    q = q_ref[0].astype(jnp.float32)                         # (Cq*G, D)

    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(ok, s, NEG_INF)
    _accumulate(s, ok, v, m_ref, l_ref, acc_ref)

    @pl.when(ti == pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def chunk_attention(q, k, v, valid, *, bt=512, interpret=False):
    """q: (B, Cq, Hq, D); k/v: (B, Hkv, T, D); valid: (B, Hkv, Cq, T).

    Returns (B, Cq, Hq, D). Matches kernels.ref.chunk_attention_ref
    (all-invalid rows yield 0).
    """
    b, cq, hq, d = q.shape
    h_kv, t = k.shape[1], k.shape[2]
    g = hq // h_kv
    qg = q.reshape(b, cq, h_kv, g, d).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(b * h_kv, cq * g, d)
    kt = k.reshape(b * h_kv, t, d)
    vt = v.reshape(b * h_kv, t, d)
    vd = valid.reshape(b * h_kv, cq, t).astype(jnp.int32)

    bt_ = min(bt, t)
    nt = pl.cdiv(t, bt_)
    out = pl.pallas_call(
        functools.partial(_chunk_kernel, bt=bt_, seq_t=t, cq=cq, group=g),
        grid=(b * h_kv, nt),
        in_specs=[
            pl.BlockSpec((1, cq * g, d), lambda bh, ti: (bh, 0, 0)),
            pl.BlockSpec((1, bt_, d), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, bt_, d), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, cq, bt_), lambda bh, ti: (bh, 0, ti)),
        ],
        out_specs=pl.BlockSpec((1, cq * g, d), lambda bh, ti: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h_kv, cq * g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq * g, 1), jnp.float32),
            pltpu.VMEM((cq * g, 1), jnp.float32),
            pltpu.VMEM((cq * g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, vd)
    out = out.reshape(b, h_kv, cq, g, d).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, cq, hq, d)


def _paged_kernel(q_ref, kp_ref, vp_ref, ps_ref, st_ref, kn_ref, vn_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, bpp, page, n_pages, npt,
                  cq, group):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                         # (Cq*G, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    start = st_ref[0, 0]

    @pl.when(ti < npt)
    def _pages():
        # fused gather: validity from page metadata, in-kernel. Every
        # buffered key precedes every chunk query (pos < start), so the
        # mask is per-KEY — no Cq axis.
        ps = ps_ref[...].reshape(bpp, 1)                     # (BPP, 1)
        pidx = ti * bpp + jax.lax.broadcasted_iota(
            jnp.int32, (bpp, page), 0)
        offs = jax.lax.broadcasted_iota(jnp.int32, (bpp, page), 1)
        pos = ps + offs
        ok2 = (pidx < n_pages) & (ps >= 0) & (pos < start)   # (BPP, P)
        ok = ok2.reshape(1, bpp * page)
        k = jnp.where(ok[0][:, None], kp_ref[0].astype(jnp.float32), 0.0)
        v = jnp.where(ok[0][:, None], vp_ref[0].astype(jnp.float32), 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(ok, s, NEG_INF)
        _accumulate(s, ok, v, m_ref, l_ref, acc_ref)

    @pl.when(ti == npt)
    def _chunk():
        # intra-chunk phase: STATIC causal mask — key j valid for query
        # row r = c*G + g iff j <= c.
        k = kn_ref[0].astype(jnp.float32)                    # (Cq, D)
        v = vn_ref[0].astype(jnp.float32)
        rows_c = jax.lax.broadcasted_iota(
            jnp.int32, (cq * group, cq), 0) // group
        cols = jax.lax.broadcasted_iota(jnp.int32, (cq * group, cq), 1)
        ok = cols <= rows_c
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(ok, s, NEG_INF)
        _accumulate(s, ok, v, m_ref, l_ref, acc_ref)

    @pl.when(ti == npt)
    def _finish():
        # every query row attends at least itself, so l > 0; keep the
        # guard anyway to match the shared epilogue contract
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def chunk_attention_paged(q, k_pages, v_pages, page_start, start, k_new,
                          v_new, *, bt=512, interpret=False):
    """Chunked-prefill retrieval attention with the page gather fused.

    q: (B, Cq, Hq, D); k_pages/v_pages: (B, Hr, C, P, D) — the PRE-append
    paged buffer; page_start: (B, Hr, C) absolute position of each page's
    first token (-1 = unwritten); start: (B,) tokens already admitted;
    k_new/v_new: (B, Cq, Hr, D) the chunk's own keys/values (roped,
    kv-head order). Returns (B, Cq, Hq, D). Matches
    kernels.ref.chunk_attention_paged_ref.
    """
    b, cq, hq, d = q.shape
    hr, c, page = k_pages.shape[1:4]
    g = hq // hr
    bh = b * hr
    qg = q.reshape(b, cq, hr, g, d).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(bh, cq * g, d)
    kp = k_pages.reshape(bh, c * page, d)
    vp = v_pages.reshape(bh, c * page, d)
    ps = page_start.reshape(bh, c).astype(jnp.int32)
    st = jnp.repeat(jnp.asarray(start, jnp.int32).reshape(b), hr)
    st = st.reshape(bh, 1)
    kn = k_new.transpose(0, 2, 1, 3).reshape(bh, cq, d)
    vn = v_new.transpose(0, 2, 1, 3).reshape(bh, cq, d)

    bpp = max(1, min(bt // page, c))    # whole pages per KV tile
    npt = pl.cdiv(c, bpp)
    last = npt - 1
    out = pl.pallas_call(
        functools.partial(_paged_kernel, bpp=bpp, page=page, n_pages=c,
                          npt=npt, cq=cq, group=g),
        grid=(bh, npt + 1),
        in_specs=[
            pl.BlockSpec((1, cq * g, d), lambda bh_, ti: (bh_, 0, 0)),
            pl.BlockSpec((1, bpp * page, d),
                         lambda bh_, ti: (bh_, jnp.minimum(ti, last), 0)),
            pl.BlockSpec((1, bpp * page, d),
                         lambda bh_, ti: (bh_, jnp.minimum(ti, last), 0)),
            pl.BlockSpec((1, bpp),
                         lambda bh_, ti: (bh_, jnp.minimum(ti, last))),
            pl.BlockSpec((1, 1), lambda bh_, ti: (bh_, 0)),
            pl.BlockSpec((1, cq, d), lambda bh_, ti: (bh_, 0, 0)),
            pl.BlockSpec((1, cq, d), lambda bh_, ti: (bh_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, cq * g, d), lambda bh_, ti: (bh_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, cq * g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq * g, 1), jnp.float32),
            pltpu.VMEM((cq * g, 1), jnp.float32),
            pltpu.VMEM((cq * g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kp, vp, ps, st, kn, vn)
    out = out.reshape(b, hr, cq, g, d).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, cq, hq, d)
