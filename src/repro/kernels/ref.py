"""Pure-jnp reference oracles for every Pallas kernel.

These are also the implementation used when lowering for non-TPU backends
(the multi-pod dry-run lowers these; XLA's cost model sees native HLO).
Shapes use the conventions:

  q  (prefill): (B, S, Hq, D)      q (decode): (B, Hq, D)
  k/v (prefill): (B, S, Hkv, D)    gathered kv (decode): (B, Hkv, T, D)

GQA is handled by broadcasting each kv head over its group of q heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def _gqa_expand(x: Array, n_q_heads: int) -> Array:
    """(B, ..., Hkv, ...) -> repeat kv heads to match q heads on axis 2."""
    h_kv = x.shape[2]
    group = n_q_heads // h_kv
    if group == 1:
        return x
    return jnp.repeat(x, group, axis=2)


# ---------------------------------------------------------------------------
# Prefill attention (causal, optional sliding window + attention sinks)
# ---------------------------------------------------------------------------


CHUNK_THRESHOLD = 2048  # switch to the scan-over-q-chunks form above this
Q_CHUNK = 1024


def flash_attention_ref(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    sink: int = 0,
    q_offset: int = 0,
) -> Array:
    """Reference attention.

    q: (B, Sq, Hq, D), k/v: (B, Sk, Hkv, D). window>0 keeps j in
    (i-window, i]; sink>0 additionally keeps j < sink (StreamingLLM).
    q_offset: absolute position of q[0] (for chunked prefill).
    Returns (B, Sq, Hq, D).

    For long sequences this dispatches to a chunked form (exact; scan over
    q blocks) so the S×S logits are never materialized — the pure-jnp path
    stays usable at 32k–500k for the dry-run and its HLO reflects the
    FLOPs/bytes a production kernel would do (window layers slice K to the
    window span instead of masking the full row).
    """
    sq, sk = q.shape[1], k.shape[1]
    if sq > CHUNK_THRESHOLD and sq % Q_CHUNK == 0:
        return _flash_attention_ref_chunked(
            q, k, v, causal=causal, window=window, sink=sink,
            q_offset=q_offset)
    return _flash_attention_ref_dense(
        q, k, v, causal=causal, window=window, sink=sink, q_offset=q_offset)


def _flash_attention_ref_dense(q, k, v, *, causal, window, sink, q_offset):
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    k = _gqa_expand(k, hq)
    v = _gqa_expand(v, hq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    # keep K/V in storage dtype; accumulate in f32 via the MXU
    # (an .astype(f32) here would be hoisted through gathers by XLA and
    # materialize whole caches in f32 — see EXPERIMENTS.md §Perf)
    logits = jnp.einsum("bihd,bjhd->bhij", q.astype(k.dtype), k,
                        preferred_element_type=jnp.float32) * scale
    i = jnp.arange(sq)[:, None] + q_offset
    j = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= j <= i
    if window > 0:
        win = j > (i - window)
        if sink > 0:
            win |= j < sink
        mask &= win
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhij,bjhd->bihd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _flash_attention_ref_chunked(q, k, v, *, causal, window, sink, q_offset):
    """Exact attention, scanning over q chunks of Q_CHUNK.

    Full-attention layers: each chunk sees K[:, :chunk_end] via masking of
    the full K (XLA DCE can't trim a traced slice per-iteration, so the
    cost model charges the causal-full quadratic — correct for roofline).
    Window layers: each chunk slices K to [start-window, end) + sink block,
    so local layers cost O(S·window), not O(S²).
    """
    from repro.runtime import hints

    b, sq, hq, d = q.shape
    sk = k.shape[1]
    kx = hints.attn_kv(_gqa_expand(k, hq))
    vx = hints.attn_kv(_gqa_expand(v, hq))
    nq = sq // Q_CHUNK
    qc = q.astype(k.dtype).reshape(b, nq, Q_CHUNK, hq, d)
    # sequence-parallel attention: balanced for any head count (see
    # runtime/hints.py; no-op outside a mesh context)
    qc = hints.attn_q_chunks(qc)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    if window > 0:
        span = Q_CHUNK + window  # static k-slice width per chunk
        # left-pad K/V by `window` so the slice never goes negative
        kpad = jnp.pad(kx, ((0, 0), (window, 0), (0, 0), (0, 0)))
        vpad = jnp.pad(vx, ((0, 0), (window, 0), (0, 0), (0, 0)))

        def chunk_fn(_, ci):
            qi = qc[:, ci]                                  # (B,CQ,H,D)
            start = ci * Q_CHUNK
            ipos = q_offset + start + jnp.arange(Q_CHUNK)   # q positions
            # keys [start+q_offset-window, start+q_offset+CQ) -> padded
            # slice starting at start+q_offset
            kw = jax.lax.dynamic_slice_in_dim(kpad, start + q_offset, span, 1)
            vw = jax.lax.dynamic_slice_in_dim(vpad, start + q_offset, span, 1)
            jpos = (start + q_offset - window) + jnp.arange(span)
            logits = jnp.einsum("bihd,bjhd->bhij", qi, kw,
                                preferred_element_type=jnp.float32) * scale
            m = (jpos[None, :] <= ipos[:, None])            # causal
            m &= jpos[None, :] > (ipos[:, None] - window)   # window
            m &= (jpos >= 0)[None, :]                       # pad
            logits = jnp.where(m[None, None], logits, NEG_INF)
            if sink > 0:
                ls = jnp.einsum("bihd,bjhd->bhij", qi, kx[:, :sink],
                                preferred_element_type=jnp.float32) * scale
                spos = jnp.arange(sink)
                # sink attended iff causal AND not already in the window
                ms = (spos[None, :] <= ipos[:, None]) & \
                     (spos[None, :] <= (ipos[:, None] - window))
                ls = jnp.where(ms[None, None], ls, NEG_INF)
                logits = jnp.concatenate([ls, logits], axis=-1)
                vw = jnp.concatenate([vx[:, :sink], vw], axis=1)
            p = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bhij,bjhd->bihd", p.astype(vw.dtype), vw,
                             preferred_element_type=jnp.float32)
            return None, out

        _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(nq))
    else:
        jpos = jnp.arange(sk)

        def chunk_fn(_, ci):
            qi = qc[:, ci]
            ipos = q_offset + ci * Q_CHUNK + jnp.arange(Q_CHUNK)
            logits = jnp.einsum("bihd,bjhd->bhij", qi, kx,
                                preferred_element_type=jnp.float32) * scale
            if causal:
                m = jpos[None, :] <= ipos[:, None]
                logits = jnp.where(m[None, None], logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bhij,bjhd->bihd", p.astype(vx.dtype), vx,
                             preferred_element_type=jnp.float32)
            return None, out

        _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(nq))
    # outs: (nq, B, CQ, H, D) -> (B, S, H, D)
    outs = hints.attn_out(outs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention over a gathered (compacted) KV buffer with validity mask
# ---------------------------------------------------------------------------


def paged_attention_ref(
    q: Array,
    k: Array,
    v: Array,
    valid: Array,
) -> Array:
    """q: (B, Hq, D); k/v: (B, Hkv, T, D); valid: (B, Hkv, T) bool.

    Computes softmax(q·kᵀ)·v over valid positions. Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    h_kv = k.shape[1]
    group = hq // h_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = q.reshape(b, h_kv, group, d).astype(k.dtype)
    logits = jnp.einsum("bhgd,bhtd->bhgt", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[:, :, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # guard the all-invalid case (empty context): softmax of all -inf
    any_valid = jnp.any(valid, axis=-1)[:, :, None, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhgt,bhtd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


def chunk_attention_ref(q: Array, k: Array, v: Array, valid: Array) -> Array:
    """Multi-query attention over a gathered KV buffer (chunked prefill).

    q: (B, Cq, Hq, D) — one chunk of queries per slot; k/v:
    (B, Hkv, T, D); valid: (B, Hkv, Cq, T) bool — per-QUERY validity
    (causal / sink+local masks are computed by the caller from absolute
    positions). The single-query ``paged_attention_ref`` is the Cq == 1
    special case. Returns (B, Cq, Hq, D); all-invalid rows yield 0.
    """
    b, cq, hq, d = q.shape
    h_kv = k.shape[1]
    group = hq // h_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = q.reshape(b, cq, h_kv, group, d).astype(k.dtype)
    logits = jnp.einsum("bchgd,bhtd->bhgct", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[:, :, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    any_valid = jnp.any(valid, axis=-1)[:, :, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhgct,bhtd->bchgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, cq, hq, d).astype(q.dtype)


def chunk_attention_paged_ref(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    page_start: Array,
    start: Array,
    k_new: Array,
    v_new: Array,
) -> Array:
    """Chunked-prefill retrieval attention with the page gather fused.

    q: (B, Cq, Hq, D) — one chunk of queries per slot; k_pages/v_pages:
    (B, Hr, C, P, D) — the PRE-append paged buffer; page_start:
    (B, Hr, C) absolute position of each page's first token (-1 =
    unwritten); start: (B,) tokens already admitted per slot; k_new/v_new:
    (B, Cq, Hr, D) — the chunk's own keys/values (roped, kv-head order).

    Because the buffer is pre-append, every buffered key precedes every
    chunk query (pos < start <= start + c), so cache validity is per-KEY
    — no (B, H, Cq, T) mask is ever materialized — and the intra-chunk
    part is a static causal triangle (key j attends query c iff j <= c).
    The union of the two key sets equals ``chunk_attention_ref`` over the
    post-append buffer with the positional mask (position math is inlined
    here; core.paging imports kernels.ops, so importing it back would be
    circular). Every query row attends at least itself, so no all-invalid
    guard is needed. Returns (B, Cq, Hq, D).
    """
    b, cq, hq, d = q.shape
    hr, c, p = k_pages.shape[1:4]
    group = hq // hr
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    kb = k_pages.reshape(b, hr, c * p, d)
    vb = v_pages.reshape(b, hr, c * p, d)
    start = jnp.asarray(start, jnp.int32).reshape(b)
    offs = jnp.arange(p, dtype=jnp.int32)
    pos = (page_start[..., None] + offs).reshape(b, hr, c * p)
    written = jnp.broadcast_to(
        (page_start >= 0)[..., None], (b, hr, c, p)).reshape(b, hr, c * p)
    cache_ok = written & (pos < start[:, None, None])        # (B, Hr, C*P)

    qg = q.reshape(b, cq, hr, group, d).astype(kb.dtype)
    lc = jnp.einsum("bchgd,bhtd->bhgct", qg, kb,
                    preferred_element_type=jnp.float32) * scale
    lc = jnp.where(cache_ok[:, :, None, None, :], lc, NEG_INF)
    kn = k_new.astype(kb.dtype)
    ln = jnp.einsum("bchgd,bjhd->bhgcj", qg, kn,
                    preferred_element_type=jnp.float32) * scale
    causal = jnp.arange(cq)[:, None] >= jnp.arange(cq)[None, :]
    ln = jnp.where(causal[None, None, None], ln, NEG_INF)
    probs = jax.nn.softmax(jnp.concatenate([lc, ln], axis=-1), axis=-1)
    out = jnp.einsum("bhgct,bhtd->bchgd", probs[..., : c * p].astype(
        vb.dtype), vb, preferred_element_type=jnp.float32)
    out = out + jnp.einsum(
        "bhgcj,bjhd->bchgd", probs[..., c * p:].astype(v_new.dtype),
        v_new.astype(vb.dtype), preferred_element_type=jnp.float32)
    return out.reshape(b, cq, hq, d).astype(q.dtype)


def paged_attention_partial_ref(q, k, v, valid):
    """Partial (unnormalized) attention for cross-shard combine.

    q: (B, Hq, D); k/v: (B, Hkv, T, D); valid: (B, Hkv, T).

    Shape contract (any kernel impl — e.g. the Pallas
    paged_attention_partial — must match it):
      m: (B, Hq) f32 — running max of valid logits, NEG_INF (-1e30, a
         FINITE sentinel, never -inf) when a row has no valid token;
      l: (B, Hq) f32 — sum of exp(logit - m) over valid tokens, 0 for
         all-invalid rows;
      o: (B, Hq, D) f32 — unnormalized numerator sum(exp(logit - m) * v),
         0 for all-invalid rows.
    (NEG_INF, 0, 0) is the identity element of merge_partials_ref, so
    all-invalid shards drop out of the cross-shard combine exactly.
    Combine across shards with combine_partials_ref or a
    (pmax, psum, psum) collective merge.
    """
    b, hq, d = q.shape
    h_kv = k.shape[1]
    group = hq // h_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = q.reshape(b, h_kv, group, d).astype(k.dtype)
    logits = jnp.einsum("bhgd,bhtd->bhgt", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[:, :, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)                       # (B,Hkv,G)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(valid[:, :, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgt,bhtd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    m = jnp.where(jnp.isfinite(m), m, NEG_INF)
    return (m.reshape(b, hq), l.reshape(b, hq), o.reshape(b, hq, d))


def paged_attention_weights_ref(q, k, valid):
    """Softmax weights only (B, Hkv, G, T) — used for importance accumulation."""
    b, hq, d = q.shape
    h_kv = k.shape[1]
    group = hq // h_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = q.reshape(b, h_kv, group, d).astype(k.dtype)
    logits = jnp.einsum("bhgd,bhtd->bhgt", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[:, :, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    any_valid = jnp.any(valid, axis=-1)[:, :, None, None]
    return jnp.where(any_valid, p, 0.0)


# ---------------------------------------------------------------------------
# Page relevance scoring (Quest-style min/max metadata)
# ---------------------------------------------------------------------------


def page_score_ref(q: Array, tau_min: Array, tau_max: Array) -> Array:
    """q: (B, Hq, D); tau_min/max: (B, Hkv, P, D) -> scores (B, Hkv, P).

    Per q head: Σ_d max(q_d·τmin_d, q_d·τmax_d) — the Quest upper bound on
    any key's logit in the page (q_d·k_d is linear in k_d, so it is
    maximized at an interval endpoint). Computed MXU-friendly as
    relu(q)·τmax + min(q,0)·τmin, which is exactly the per-coordinate max.
    GQA groups aggregate by summing over the group's q heads.
    """
    b, hq, d = q.shape
    h_kv = tau_min.shape[1]
    group = hq // h_kv
    qg = q.reshape(b, h_kv, group, d).astype(tau_min.dtype)
    qp = jnp.maximum(qg, 0)
    qn = jnp.minimum(qg, 0)
    hi = jnp.einsum("bhgd,bhpd->bhgp", qp, tau_max,
                    preferred_element_type=jnp.float32)
    lo = jnp.einsum("bhgd,bhpd->bhgp", qn, tau_min,
                    preferred_element_type=jnp.float32)
    return (hi + lo).sum(axis=2)


# ---------------------------------------------------------------------------
# Online-softmax partial combine (memory-compute co-placement cross-bank op)
# ---------------------------------------------------------------------------


def merge_partials_ref(m: Array, l: Array, o: Array, axis: int = 0):
    """Merge flash partials into ONE partial (still unnormalized).

    m/l: (N, ...); o: (N, ..., D) stacked on ``axis`` — each triple obeys
    the paged_attention_partial_ref shape contract. Returns (m', l', o')
    with the stack axis reduced. The merge is associative and commutative
    (up to float reassociation), with identity (NEG_INF, 0, 0) — the
    algebra that makes bank-count and shard-order irrelevant to the
    co-placed decode (tested in tests/test_kernels.py).
    """
    m_g = jnp.max(m, axis=axis)
    corr = jnp.exp(m - jnp.expand_dims(m_g, axis))
    l_g = jnp.sum(l * corr, axis=axis)
    o_g = jnp.sum(o * corr[..., None], axis=axis)
    return m_g, l_g, o_g


def combine_partials_ref(m: Array, l: Array, o: Array, axis: int = 0):
    """Combine flash-attention partials computed on different banks/shards.

    m: (N, ...) running max, l: (N, ...) sumexp, o: (N, ..., D) partial
    numerator (sum of exp(logit - m) * v). Returns combined output (..., D).
    Exact: softmax over the union equals the weighted combine
    (= merge_partials_ref followed by the l-normalization).
    """
    _, l_g, o_g = merge_partials_ref(m, l, o, axis=axis)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]
