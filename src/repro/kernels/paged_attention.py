"""Pallas TPU decode attention over a gathered (compacted) KV buffer.

The top-k page gather happens outside (a sharded XLA gather — on TPU a
scalar-prefetch in-kernel gather buys nothing for this access pattern since
whole pages are contiguous). The kernel streams the compacted KV through
VMEM in (BT, D) tiles with online softmax; q is the (G, D) GQA group,
resident in VMEM for the whole program — this mirrors the paper's
"sink+local in logic-die SRAM" co-design: the hot operand stays on-die
while KV streams past it.

Layout: q (BH, G, D); kv (BH, T, D); valid (BH, T) -> out (BH, G, D),
where BH = B * Hkv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bt, seq_t):
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = ti * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    inb = rows < seq_t
    k = jnp.where(inb, k_ref[0].astype(jnp.float32), 0.0)   # (BT, D)
    v = jnp.where(inb, v_ref[0].astype(jnp.float32), 0.0)   # (BT, D)
    ok = inb[:, 0] & (valid_ref[0] != 0)                     # (BT,)
    q = q_ref[0].astype(jnp.float32)                         # (G, D)

    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, BT)
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok[None, :], p, 0.0)  # all-masked tile: exp(-inf - -inf)=1
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ti == nt - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def paged_attention(q, k, v, valid, *, bt=512, interpret=False):
    """q: (B, Hq, D); k/v: (B, Hkv, T, D); valid: (B, Hkv, T) bool.

    Returns (B, Hq, D). Matches kernels.ref.paged_attention_ref.
    """
    b, hq, d = q.shape
    h_kv, t = k.shape[1], k.shape[2]
    g = hq // h_kv
    qg = q.reshape(b * h_kv, g, d)
    kt = k.reshape(b * h_kv, t, d)
    vt = v.reshape(b * h_kv, t, d)
    vd = valid.reshape(b * h_kv, t).astype(jnp.int32)

    bt_ = min(bt, t)
    nt = pl.cdiv(t, bt_)
    out = pl.pallas_call(
        functools.partial(_kernel, bt=bt_, seq_t=t),
        grid=(b * h_kv, nt),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ti: (bh, 0, 0)),
            pl.BlockSpec((1, bt_, d), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, bt_, d), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, bt_), lambda bh, ti: (bh, ti)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, ti: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h_kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, vd)
    return out.reshape(b, hq, d)
