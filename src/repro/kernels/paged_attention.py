"""Pallas TPU decode attention over a gathered (compacted) KV buffer.

The top-k page gather happens outside (a sharded XLA gather — on TPU a
scalar-prefetch in-kernel gather buys nothing for this access pattern since
whole pages are contiguous). The kernels stream the compacted KV through
VMEM in (BT, D) tiles with online softmax; q is the (G, D) GQA group,
resident in VMEM for the whole program — this mirrors the paper's
"sink+local in logic-die SRAM" co-design: the hot operand stays on-die
while KV streams past it.

Three entry points (see docs/kernels.md for the full catalog):

  paged_attention          — normalized decode attention (single device).
  paged_attention_partial  — the same online-softmax stream, but emitting
                             the UNNORMALIZED flash partials (m, l, o) a
                             bank/shard contributes under memory-compute
                             co-placement (paper §IV-B). Contract matches
                             kernels.ref.paged_attention_partial_ref.
  combine_partials         — fused cross-bank epilogue: max/rescale/
                             sum/divide over the shard axis in one kernel
                             (the paper's cross-bank softmax merge).

Layout: q (BH, G, D); kv (BH, T, D); valid (BH, T) -> out (BH, G, D),
where BH = B * Hkv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _stream_tile(q_ref, k_ref, v_ref, valid_ref, m_ref, l_ref, acc_ref, *,
                 bt, seq_t):
    """One (BT, D) KV tile of the online-softmax stream: init on the first
    tile, then masked rescale-and-accumulate into the (m, l, acc) VMEM
    state. Shared by the normalized and partial kernels — only their
    epilogues differ."""
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = ti * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    inb = rows < seq_t
    k = jnp.where(inb, k_ref[0].astype(jnp.float32), 0.0)   # (BT, D)
    v = jnp.where(inb, v_ref[0].astype(jnp.float32), 0.0)   # (BT, D)
    ok = inb[:, 0] & (valid_ref[0] != 0)                     # (BT,)
    q = q_ref[0].astype(jnp.float32)                         # (G, D)

    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, BT)
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok[None, :], p, 0.0)  # all-masked tile: exp(-inf - -inf)=1
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _stream_call(kernel, q, k, v, valid, *, bt, interpret, out_specs,
                 out_shape):
    """Shared pallas_call setup for the KV-streaming decode kernels:
    fold (B, Hkv) into the BH grid axis, tile T by ``bt``, and allocate
    the (m, l, acc) online-softmax scratch."""
    b, hq, d = q.shape
    h_kv, t = k.shape[1], k.shape[2]
    g = hq // h_kv
    qg = q.reshape(b * h_kv, g, d)
    kt = k.reshape(b * h_kv, t, d)
    vt = v.reshape(b * h_kv, t, d)
    vd = valid.reshape(b * h_kv, t).astype(jnp.int32)

    bt_ = min(bt, t)
    nt = pl.cdiv(t, bt_)
    return pl.pallas_call(
        functools.partial(kernel, bt=bt_, seq_t=t),
        grid=(b * h_kv, nt),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ti: (bh, 0, 0)),
            pl.BlockSpec((1, bt_, d), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, bt_, d), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, bt_), lambda bh, ti: (bh, ti)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, vd)


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bt, seq_t):
    _stream_tile(q_ref, k_ref, v_ref, valid_ref, m_ref, l_ref, acc_ref,
                 bt=bt, seq_t=seq_t)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def paged_attention(q, k, v, valid, *, bt=512, interpret=False):
    """q: (B, Hq, D); k/v: (B, Hkv, T, D); valid: (B, Hkv, T) bool.

    Returns (B, Hq, D). Matches kernels.ref.paged_attention_ref.
    """
    b, hq, d = q.shape
    h_kv = k.shape[1]
    g = hq // h_kv
    out = _stream_call(
        _kernel, q, k, v, valid, bt=bt, interpret=interpret,
        out_specs=pl.BlockSpec((1, g, d), lambda bh, ti: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h_kv, g, d), q.dtype))
    return out.reshape(b, hq, d)


def _partial_kernel(q_ref, k_ref, v_ref, valid_ref, m_out, l_out, o_out,
                    m_ref, l_ref, acc_ref, *, bt, seq_t):
    """Same online-softmax stream as _kernel, but the epilogue emits the
    raw (m, l, acc) accumulator state instead of normalizing — the shard's
    contribution to the cross-bank combine."""
    _stream_tile(q_ref, k_ref, v_ref, valid_ref, m_ref, l_ref, acc_ref,
                 bt=bt, seq_t=seq_t)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _finish():
        m_out[0] = m_ref[...][:, 0]
        l_out[0] = l_ref[...][:, 0]
        o_out[0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def paged_attention_partial(q, k, v, valid, *, bt=512, interpret=False):
    """Partial (unnormalized) decode attention for the cross-shard combine.

    q: (B, Hq, D); k/v: (B, Hkv, T, D); valid: (B, Hkv, T) bool.
    Returns (m, l, o): running max (B, Hq) f32, sumexp (B, Hq) f32,
    numerator (B, Hq, D) f32 — matching
    kernels.ref.paged_attention_partial_ref (all-invalid rows are the
    identity element m=NEG_INF, l=0, o=0).
    """
    b, hq, d = q.shape
    h_kv = k.shape[1]
    g = hq // h_kv
    m, l, o = _stream_call(
        _partial_kernel, q, k, v, valid, bt=bt, interpret=interpret,
        out_specs=[
            pl.BlockSpec((1, g), lambda bh, ti: (bh, 0)),
            pl.BlockSpec((1, g), lambda bh, ti: (bh, 0)),
            pl.BlockSpec((1, g, d), lambda bh, ti: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h_kv, g), jnp.float32),
            jax.ShapeDtypeStruct((b * h_kv, g), jnp.float32),
            jax.ShapeDtypeStruct((b * h_kv, g, d), jnp.float32),
        ])
    return m.reshape(b, hq), l.reshape(b, hq), o.reshape(b, hq, d)


def _combine_kernel(m_ref, l_ref, o_ref, out_ref, *, br, n_rows):
    """Fused cross-bank epilogue: global max, rescale, sum, divide."""
    ri = pl.program_id(0)
    rows = ri * br + jax.lax.broadcasted_iota(jnp.int32, (1, br), 1)
    inb = rows < n_rows                                      # (1, BR)
    m = jnp.where(inb, m_ref[...], NEG_INF)                  # (N, BR)
    l = jnp.where(inb, l_ref[...], 0.0)
    o = jnp.where(inb[..., None], o_ref[...], 0.0)           # (N, BR, D)
    m_g = jnp.max(m, axis=0)                                 # (BR,)
    corr = jnp.exp(m - m_g[None, :])                         # (N, BR)
    l_g = jnp.sum(l * corr, axis=0)
    o_g = jnp.sum(o * corr[..., None], axis=0)               # (BR, D)
    out_ref[...] = o_g / jnp.maximum(l_g, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def combine_partials(m, l, o, *, br=128, interpret=False):
    """Fused flash-partial combine over the leading shard axis.

    m/l: (N, B, Hq) f32; o: (N, B, Hq, D) f32 — the stacked per-bank
    partials (e.g. from an all_gather). Returns the combined output
    (B, Hq, D) f32, matching kernels.ref.combine_partials_ref(axis=0).
    """
    n, b_, hq = m.shape
    d = o.shape[-1]
    r = b_ * hq
    mr = m.reshape(n, r)
    lr = l.reshape(n, r)
    orr = o.reshape(n, r, d)

    br_ = min(br, r)
    nr = pl.cdiv(r, br_)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, br=br_, n_rows=r),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((n, br_), lambda ri: (0, ri)),
            pl.BlockSpec((n, br_), lambda ri: (0, ri)),
            pl.BlockSpec((n, br_, d), lambda ri: (0, ri, 0)),
        ],
        out_specs=pl.BlockSpec((br_, d), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(mr, lr, orr)
    return out.reshape(b_, hq, d)
