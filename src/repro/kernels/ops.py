"""Jit'd dispatch wrappers for the Pallas kernels.

``impl`` selects the backend:
  "ref"    — pure-jnp oracle (kernels/ref.py). Used for CPU tests and for
             the multi-pod dry-run (native HLO is what GSPMD partitions and
             what cost_analysis models).
  "pallas" — Pallas TPU kernel (pl.pallas_call). On non-TPU backends the
             wrappers run the kernel in interpret mode so correctness is
             testable everywhere. ("kernel" is accepted as a legacy alias.)

Unknown ``impl`` strings raise ValueError (they used to fall through to
the kernel path silently). See docs/kernels.md for the kernel catalog.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

_INTERPRET = jax.default_backend() != "tpu"

VALID_IMPLS = ("ref", "pallas")
_ALIASES = {"kernel": "pallas"}
_warned_aliases: set[str] = set()


def resolve_impl(impl: str) -> str:
    """Canonicalize an ``impl`` string; raise ValueError if unknown.

    Legacy aliases (``"kernel"``) resolve to their canonical impl but
    emit a DeprecationWarning once per process — they will be removed
    after one release.
    """
    if impl in _ALIASES:
        canonical = _ALIASES[impl]
        if impl not in _warned_aliases:
            _warned_aliases.add(impl)
            warnings.warn(
                f"impl={impl!r} is a deprecated alias for "
                f"{canonical!r} and will be removed; pass "
                f"{canonical!r} instead", DeprecationWarning,
                stacklevel=2)
        impl = canonical
    if impl not in VALID_IMPLS:
        raise ValueError(
            f"unknown attention impl {impl!r}; valid impls: "
            f"{', '.join(VALID_IMPLS)} (legacy alias: "
            f"{', '.join(_ALIASES)})")
    return impl


def flash_attention(q, k, v, *, causal=True, window=0, sink=0, q_offset=0,
                    impl="ref"):
    if resolve_impl(impl) == "ref":
        return _ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, sink=sink, q_offset=q_offset)
    from repro.kernels import flash_attention as fk
    return fk.flash_attention(
        q, k, v, causal=causal, window=window, sink=sink, q_offset=q_offset,
        interpret=_INTERPRET)


def paged_attention(q, k, v, valid, *, impl="ref"):
    if resolve_impl(impl) == "ref":
        return _ref.paged_attention_ref(q, k, v, valid)
    from repro.kernels import paged_attention as pk
    return pk.paged_attention(q, k, v, valid, interpret=_INTERPRET)


def chunk_attention(q, k, v, valid, *, impl="ref"):
    """Multi-query attention over a gathered KV buffer with per-query
    validity (the chunked-prefill body; kernels/ref.py for the shape
    contract). impl="pallas" streams KV tiles past the VMEM-resident
    chunk of queries with online softmax (kernels/chunk_attention.py);
    it used to silently fall back to the reference body."""
    if resolve_impl(impl) == "ref":
        return _ref.chunk_attention_ref(q, k, v, valid)
    from repro.kernels import chunk_attention as ck
    return ck.chunk_attention(q, k, v, valid, interpret=_INTERPRET)


def chunk_attention_paged(q, k_pages, v_pages, page_start, start, k_new,
                          v_new, *, impl="ref"):
    """Chunked-prefill retrieval attention with the page gather fused:
    attends the PRE-append paged buffer (per-key validity from
    page_start) plus the chunk's own KV (static causal mask) in one
    online-softmax stream — no materialized (B, H, Cq, T) mask. See
    kernels.ref.chunk_attention_paged_ref for the shape contract.

    The chunk KV is cast to the cache dtype first so both impls attend
    exactly what a post-append body would have read back."""
    k_new = k_new.astype(k_pages.dtype)
    v_new = v_new.astype(v_pages.dtype)
    if resolve_impl(impl) == "ref":
        return _ref.chunk_attention_paged_ref(
            q, k_pages, v_pages, page_start, start, k_new, v_new)
    from repro.kernels import chunk_attention as ck
    return ck.chunk_attention_paged(
        q, k_pages, v_pages, page_start, start, k_new, v_new,
        interpret=_INTERPRET)


def paged_attention_partial(q, k, v, valid, *, impl="ref"):
    """Per-shard flash partials (m, l, o) — see
    kernels.ref.paged_attention_partial_ref for the shape contract."""
    if resolve_impl(impl) == "ref":
        return _ref.paged_attention_partial_ref(q, k, v, valid)
    from repro.kernels import paged_attention as pk
    return pk.paged_attention_partial(q, k, v, valid, interpret=_INTERPRET)


def combine_partials(m, l, o, *, axis=0, impl="ref"):
    """Combine stacked flash partials into the normalized output.

    m/l: (N, ..., Hq); o: (N, ..., Hq, D) stacked on ``axis``. The pallas
    impl is the fused cross-bank epilogue and requires axis=0 and the
    (N, B, Hq[, D]) layout the co-placement decode produces.
    """
    if resolve_impl(impl) == "ref":
        return _ref.combine_partials_ref(m, l, o, axis=axis)
    if axis != 0:
        raise ValueError(f"pallas combine_partials requires axis=0, "
                         f"got axis={axis}")
    from repro.kernels import paged_attention as pk
    return pk.combine_partials(m, l, o, interpret=_INTERPRET)


def page_score(q, tau_min, tau_max, *, impl="ref"):
    if resolve_impl(impl) == "ref":
        return _ref.page_score_ref(q, tau_min, tau_max)
    from repro.kernels import page_score as sk
    return sk.page_score(q, tau_min, tau_max, interpret=_INTERPRET)
