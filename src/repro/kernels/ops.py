"""Jit'd dispatch wrappers for the Pallas kernels.

``impl`` selects the backend:
  "ref"    — pure-jnp oracle (kernels/ref.py). Used for CPU tests and for
             the multi-pod dry-run (native HLO is what GSPMD partitions and
             what cost_analysis models).
  "kernel" — Pallas TPU kernel (pl.pallas_call). On non-TPU backends the
             wrappers run the kernel in interpret mode so correctness is
             testable everywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

_INTERPRET = jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, sink=0, q_offset=0,
                    impl="ref"):
    if impl == "ref":
        return _ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, sink=sink, q_offset=q_offset)
    from repro.kernels import flash_attention as fk
    return fk.flash_attention(
        q, k, v, causal=causal, window=window, sink=sink, q_offset=q_offset,
        interpret=_INTERPRET)


def paged_attention(q, k, v, valid, *, impl="ref"):
    if impl == "ref":
        return _ref.paged_attention_ref(q, k, v, valid)
    from repro.kernels import paged_attention as pk
    return pk.paged_attention(q, k, v, valid, interpret=_INTERPRET)


def page_score(q, tau_min, tau_max, *, impl="ref"):
    if impl == "ref":
        return _ref.page_score_ref(q, tau_min, tau_max)
    from repro.kernels import page_score as sk
    return sk.page_score(q, tau_min, tau_max, interpret=_INTERPRET)
