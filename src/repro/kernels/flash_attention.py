"""Pallas TPU flash attention (prefill): causal + sliding-window + sink.

TPU-native design: the KQᵀ tiles are MXU-shaped (BQ×BK = 128×128 default),
online-softmax state (m, l, acc) lives in VMEM scratch and persists across
the innermost (k-block) grid dimension — the TPU grid is executed
sequentially minor-to-major, which replaces the CUDA-style intra-kernel
loop. The sink/window masks make this the single kernel for full causal
attention, streaming-head attention (window+sink), and gemma3 local layers
(window only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal, window, sink, q_offset, bq, bk, seq_q, seq_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # mask out-of-bounds block padding (its contents are unspecified)
    q_rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    k_rows = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
    q = jnp.where(q_rows < seq_q, q_ref[0].astype(jnp.float32), 0.0)  # (BQ, D)
    k = jnp.where(k_rows < seq_k, k_ref[0].astype(jnp.float32), 0.0)  # (BK, D)
    v = jnp.where(k_rows < seq_k, v_ref[0].astype(jnp.float32), 0.0)  # (BK, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (BQ,BK)

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < seq_k
    if causal:
        mask &= cols <= rows
    if window > 0:
        w = cols > (rows - window)
        if sink > 0:
            w |= cols < sink
        mask &= w
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # (BQ, BK)
    p = jnp.where(mask, p, 0.0)  # all-masked row: exp(-inf - -inf) = 1
    corr = jnp.exp(m_prev - m_new)               # (BQ, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sink", "q_offset", "bq", "bk",
                     "interpret"),
)
def flash_attention(q, k, v, *, causal=True, window=0, sink=0, q_offset=0,
                    bq=128, bk=128, interpret=False):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    sk_len = k.shape[1]
    h_kv = k.shape[2]
    group = hq // h_kv

    # layout: fold heads into batch; kv heads repeated per group
    qt = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1).reshape(b * hq, sk_len, d)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1).reshape(b * hq, sk_len, d)

    bq_ = min(bq, sq)
    bk_ = min(bk, sk_len)
    nq = pl.cdiv(sq, bq_)
    nk = pl.cdiv(sk_len, bk_)
    grid = (b * hq, nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window, sink=sink,
                          q_offset=q_offset, bq=bq_, bk=bk_, seq_q=sq,
                          seq_k=sk_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk_, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk_, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),   # m
            pltpu.VMEM((bq_, 1), jnp.float32),   # l
            pltpu.VMEM((bq_, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
