"""xLSTM-125M — sLSTM + mLSTM blocks, attention-free. [arXiv:2405.04517].

12L d_model=768 4H d_ff=0 vocab=50304. Blocks carry their own projections;
no separate FFN (d_ff=0). H²EAL is inapplicable — the recurrent blocks
hold constant-size state instead of a KV cache, so there is nothing to
page or sparsify; decode is constant-state.
"""
from repro.configs.base import (
    ArchConfig, H2ealConfig, MIXER_MLSTM, MIXER_SLSTM, register,
)

# xLSTM[7:1]-style: mostly mLSTM with periodic sLSTM
_PATTERN = (MIXER_MLSTM, MIXER_MLSTM, MIXER_SLSTM) * 4

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    mixer_pattern=_PATTERN,
    h2eal=H2ealConfig(enabled=False),  # attention-free: technique inapplicable
    source="arXiv:2405.04517; unverified",
))
