"""Kimi-K2-1T-A32B — trillion-param MoE, 384 experts top-8 + 1 shared.

[arXiv:2501.kimi2; unverified, paper-table]. 61L d_model=7168 64H (GQA kv=8)
per-expert d_ff=2048 vocab=163840.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=128,
    moe=MoEConfig(num_experts=384, top_k=8, shared_expert_ff=2048),
    source="arXiv:2501.kimi2; unverified",
))
