"""Configuration dataclasses for the repro framework.

Everything is a frozen dataclass so configs are hashable and usable as jit
static arguments. Arch configs live in one file per architecture under
``repro.configs`` and register themselves into ``REGISTRY``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# H2EAL technique config (the paper's contribution, attachable to any arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class H2ealConfig:
    """Hybrid static-dynamic sparse attention (paper §IV-A).

    static_sparsity: fraction of KV heads that are streaming heads (paper: 0.5).
    sink / local: token counts kept by streaming heads (and always kept by
        retrieval heads, paper §IV-A.4 "retrieval heads also attend to sink and
        local tokens" following StreamingLLM).
    page_size: contiguous KV tokens per page (paper: 32).
    select_budget: total selected length for retrieval heads (paper: 4k);
        top-k pages with k = select_budget // page_size.
    kv_budget: max resident KV tokens per retrieval head before eviction of the
        lowest-accumulated-importance page (paper "memory consideration").
        0 means no eviction (keep everything, select sparsely).
    share_window: number of consecutive decode queries sharing one page
        selection (paper follows LServe [27]).
    """

    enabled: bool = True
    static_sparsity: float = 0.5
    sink: int = 4
    local: int = 256
    page_size: int = 32
    select_budget: int = 4096
    kv_budget: int = 0
    share_window: int = 4

    @property
    def top_k_pages(self) -> int:
        return max(1, self.select_budget // self.page_size)


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

ATTN_FULL = "full"              # dense causal attention every layer
ATTN_LOCAL_GLOBAL = "local_global"  # gemma3-style N local : 1 global
MIXER_ATTENTION = "attention"
MIXER_MAMBA2 = "mamba2"
MIXER_SLSTM = "slstm"
MIXER_MLSTM = "mlstm"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    # d_ff of each expert (the arch's d_ff field is per-expert for MoE archs)
    shared_expert_ff: int = 0  # optional dense shared expert (0 = none)
    # Switch-style capacity factor; <= 0 means dropless (cap = T * top_k,
    # used by the reduced smoke configs where exactness is tested)
    capacity_factor: float = 1.25

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters, used by zamba2 hybrid layers."""

    state_dim: int = 64
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False           # qwen2
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # attention pattern
    attn_pattern: str = ATTN_FULL
    local_window: int = 0            # for local_global pattern
    local_global_ratio: int = 0      # N local layers per 1 global (gemma3: 5)
    # per-layer mixer sequence; empty -> all attention.
    # e.g. zamba2 repeats mamba2 blocks with periodic attention; xlstm
    # alternates slstm/mlstm.
    mixer_pattern: Tuple[str, ...] = ()
    # if False, the FFN exists only on attention-mixer layers (zamba2: mamba2
    # blocks carry their own projections and have no separate FFN)
    ffn_every_layer: bool = True
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    h2eal: H2ealConfig = field(default_factory=H2ealConfig)
    # modality frontend stub: inputs are precomputed embeddings, not token ids
    embed_frontend_stub: bool = False
    frontend_dim: int = 0            # dim of precomputed frame/patch embeddings
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def mixer_for_layer(self, i: int) -> str:
        if self.mixer_pattern:
            return self.mixer_pattern[i % len(self.mixer_pattern)]
        return MIXER_ATTENTION

    def layer_has_ffn(self, i: int) -> bool:
        if self.d_ff == 0 and not self.moe.enabled:
            return False
        if self.ffn_every_layer:
            return True
        return self.mixer_for_layer(i) == MIXER_ATTENTION

    def layer_is_global_attn(self, i: int) -> bool:
        """For local_global pattern: is layer i a global-attention layer."""
        if self.attn_pattern != ATTN_LOCAL_GLOBAL:
            return True
        r = self.local_global_ratio
        return (i % (r + 1)) == r

    @property
    def attention_layers(self) -> Tuple[int, ...]:
        return tuple(
            i for i in range(self.num_layers)
            if self.mixer_for_layer(i) == MIXER_ATTENTION
        )

    @property
    def has_attention(self) -> bool:
        return len(self.attention_layers) > 0

    def param_count(self) -> int:
        """Approximate parameter count N (for 6ND model-flops accounting)."""
        hd = self.resolved_head_dim
        d = self.d_model
        n = 0
        # embeddings (+ untied head)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.num_layers):
            mixer = self.mixer_for_layer(i)
            if mixer == MIXER_ATTENTION:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                n += q + kv + o
            elif mixer == MIXER_MAMBA2:
                inner = self.ssm.expand * d
                # in_proj (z,x,B,C,dt) + out_proj + conv
                n += d * (2 * inner + 2 * self.ssm.state_dim) + inner * d
                n += inner * self.ssm.conv_dim
            elif mixer in (MIXER_SLSTM, MIXER_MLSTM):
                n += 4 * d * d + d * d  # gates + out proj (approx)
            # ffn
            if not self.layer_has_ffn(i):
                n += 2 * d
                continue
            if self.moe.enabled:
                n += self.moe.num_experts * 3 * d * self.d_ff
                n += d * self.moe.num_experts  # router
                if self.moe.shared_expert_ff:
                    n += 3 * d * self.moe.shared_expert_ff
            elif self.d_ff:
                n += 3 * d * self.d_ff  # swiglu
            n += 2 * d  # norms
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.moe.enabled:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        inactive = (
            self.num_layers
            * (self.moe.num_experts - self.moe.top_k)
            * 3 * d * self.d_ff
        )
        return full - inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for LM-family archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    from repro import configs  # noqa: F401  (ensure modules imported)

    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 2 if not cfg.mixer_pattern else len(set(cfg.mixer_pattern))),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        local_window=64 if cfg.local_window else 0,
    )
    if cfg.moe.enabled:
        small["moe"] = MoEConfig(num_experts=4, top_k=2,
                                 shared_expert_ff=64 if cfg.moe.shared_expert_ff else 0,
                                 capacity_factor=0.0)  # dropless for exactness
    if cfg.mixer_pattern:
        # keep the family's block mix but short
        small["mixer_pattern"] = cfg.mixer_pattern[: max(2, min(4, len(cfg.mixer_pattern)))]
        small["num_layers"] = len(small["mixer_pattern"])
    small["h2eal"] = dataclasses.replace(
        cfg.h2eal, sink=2, local=16, page_size=8, select_budget=32, share_window=2
    )
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
