"""Qwen3-MoE-235B-A22B — 128 experts, top-8, GQA kv=4.

[hf:Qwen/Qwen3-30B-A3B; hf]. 94L d_model=4096 64H (GQA kv=4) per-expert
d_ff=1536 vocab=151936.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=8),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
