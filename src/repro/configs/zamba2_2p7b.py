"""Zamba2-2.7B — Mamba2 backbone with shared attention blocks.

[arXiv:2411.15242; hf]. 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64. We model the hybrid as a repeating pattern of five Mamba2 blocks
followed by one (attention + FFN) block; Mamba2 layers carry no FFN (the
Mamba2 block has its own in/out projections), matching Zamba2's shared-block
structure in spirit.
"""
from repro.configs.base import (
    ArchConfig, MIXER_ATTENTION, MIXER_MAMBA2, SSMConfig, register,
)

_PATTERN = (MIXER_MAMBA2,) * 5 + (MIXER_ATTENTION,)

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    mixer_pattern=_PATTERN,
    ffn_every_layer=False,
    ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2, head_dim=64, chunk=64),
    source="arXiv:2411.15242; hf",
))
