"""MusicGen-Large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]. 48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048 (EnCodec codebook). The EnCodec frontend is a STUB: input_specs()
provides precomputed frame embeddings of dim 2048.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    embed_frontend_stub=True,
    frontend_dim=2048,
    source="arXiv:2306.05284; hf",
))
