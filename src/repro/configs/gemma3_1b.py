"""Gemma3-1B — dense, 5:1 local:global attention, 128k-capable.

[hf:google/gemma-3-1b-pt; unverified]. 26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144. Local layers use a 512-token sliding window; every
6th layer is global. Global layers get H²EAL; local layers reuse the
streaming kernel (they are already static-sparse).
"""
from repro.configs.base import ATTN_LOCAL_GLOBAL, ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    tie_embeddings=True,
    attn_pattern=ATTN_LOCAL_GLOBAL,
    local_window=512,
    local_global_ratio=5,
    rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt; unverified",
))
