"""The paper's own evaluation models (§V-A.2): Mistral-7B, LLaMA2-7B,
LLaMA3-8B. Used by the hbsim benchmarks (Fig 9/10/11, Table III) and as
extra selectable archs.
"""
from repro.configs.base import ArchConfig, register

LLAMA2_7B = register(ArchConfig(
    name="llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1e4,
    source="arXiv:2307.09288",
))

LLAMA3_8B = register(ArchConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
    source="llama3",
))

MISTRAL_7B = register(ArchConfig(
    name="mistral-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1e4,
    source="mistral",
))
