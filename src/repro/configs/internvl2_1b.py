"""InternVL2-1B — InternViT frontend (stubbed) + InternLM2 LM backbone.

[arXiv:2404.16821; hf]. 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings of dim 896 concatenated ahead of the text tokens.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    rope_theta=1e6,
    embed_frontend_stub=True,
    frontend_dim=896,
    source="arXiv:2404.16821; hf",
))
