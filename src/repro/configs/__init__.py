"""Config registry: import every arch module so REGISTRY is populated."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    H2ealConfig,
    MoEConfig,
    REGISTRY,
    SHAPES,
    SSMConfig,
    ShapeConfig,
    get_arch,
    reduced,
    register,
)

# assigned architectures (public pool)
from repro.configs import internvl2_1b  # noqa: F401
from repro.configs import zamba2_2p7b  # noqa: F401
from repro.configs import gemma3_1b  # noqa: F401
from repro.configs import internlm2_20b  # noqa: F401
from repro.configs import qwen2_72b  # noqa: F401
from repro.configs import smollm_360m  # noqa: F401
from repro.configs import xlstm_125m  # noqa: F401
from repro.configs import musicgen_large  # noqa: F401
from repro.configs import qwen3_moe_235b  # noqa: F401
from repro.configs import kimi_k2_1t  # noqa: F401

# paper's own evaluation models (hbsim benchmarks)
from repro.configs import paper_models  # noqa: F401

ASSIGNED = (
    "internvl2-1b",
    "zamba2-2.7b",
    "gemma3-1b",
    "internlm2-20b",
    "qwen2-72b",
    "smollm-360m",
    "xlstm-125m",
    "musicgen-large",
    "qwen3-moe-235b-a22b",
    "kimi-k2-1t-a32b",
)
