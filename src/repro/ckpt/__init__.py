from repro.ckpt.checkpoint import (  # noqa: F401
    latest_step,
    prune_old,
    restore,
    save,
)
