"""Fault-tolerant checkpointing: manifest + raw per-leaf binaries.

Design for 1000+-node posture:
  * step-atomic: written to ``<dir>/tmp.<step>`` then ``os.replace``d to
    ``<dir>/step_<N>`` — a crash mid-save never corrupts the latest
    checkpoint; ``latest_step`` scans committed directories only.
  * reshard-on-restore: leaves are stored unsharded-logical (this container
    is single-process; a multi-host deployment writes one file per shard
    with the same manifest schema) and restored with ``jax.device_put``
    to ANY target sharding/mesh — elastic restarts onto a different mesh
    shape "just work".
  * self-describing: manifest.json carries path, shape, dtype per leaf +
    user metadata (step, data-stream position, config hash).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

try:
    import ml_dtypes  # numpy bfloat16 support (ships with jax)
except ImportError:  # pragma: no cover
    ml_dtypes = None


def _np_dtype(name: str):
    if name == "bfloat16":
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _flatten(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(directory: str, tree, *, step: int, metadata: dict | None = None):
    """Atomically write checkpoint for ``step``. Returns final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    entries = []
    for i, (path, leaf) in enumerate(_flatten(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(arr.tobytes())
        entries.append({"path": path, "file": fname,
                        "shape": list(arr.shape), "dtype": arr.dtype.name})
    manifest = {"step": step, "leaves": entries,
                "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, target_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``target_tree``.

    shardings: optional pytree (same structure) of NamedShardings — leaves
    are device_put with them (reshard-on-restore / elastic).
    Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat_t = jax.tree_util.tree_flatten_with_path(target_tree)
    paths = [jax.tree_util.keystr(p) for p, _ in flat_t[0]]
    flat_s = (jax.tree.leaves(shardings) if shardings is not None
              else [None] * len(paths))
    out = []
    for (path, ref_leaf), shard in zip(
            [(jax.tree_util.keystr(p), l) for p, l in flat_t[0]], flat_s):
        e = by_path.get(path)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        with open(os.path.join(d, e["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=_np_dtype(e["dtype"]))
        arr = arr.reshape(e["shape"])
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), out)
    return tree, manifest["metadata"]


def prune_old(directory: str, keep: int = 3):
    """Keep the newest ``keep`` checkpoints (garbage collection)."""
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)
