import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real step function (train_step for training
shapes, prefill/serve_step for inference shapes) with the production
shardings, compiles it, and records memory/cost/collective statistics for
the roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod both \
      --out results/dryrun.json
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, SHAPES, get_arch
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.runtime import hlo_stats
from repro.runtime import serve as serve_rt
from repro.runtime import sharding as shardlib
from repro.runtime import train as train_rt

# TPU v5e hardware model (roofline constants)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

# The dry-run lowers in f32: XLA:CPU float-normalizes bf16 compute into
# convert-wrapped f32 (absent on TPU where bf16 is MXU-native), which
# pollutes the byte/collective model with 3x phantom traffic. Lowering f32
# end-to-end produces a convert-free module; the production wire format is
# bf16, so data-proportional terms are scaled by 0.5.
DRYRUN_DTYPE = "float32"
BF16_WIRE_FACTOR = 0.5


def _train_tcfg(cfg):
    # MoE dispatch buffers, dense-72B activations, and the mamba2 chunk
    # decay tensors all need microbatching at global_batch 256
    mb = 8 if (cfg.moe.enabled or cfg.d_model >= 6144
               or cfg.family == "hybrid") else 1
    return train_rt.TrainConfig(microbatches=mb, remat=True,
                                grad_dtype="bf16")


def _round_capacity(cfg, capacity: int, mesh) -> int:
    """Round page capacity up so the page dim divides the model axis
    (required by the coplace_shmap layout; harmless otherwise)."""
    p = max(cfg.h2eal.page_size, 1)
    m = mesh.shape["model"]
    pages = -(-capacity // p)
    pages = -(-pages // m) * m
    return pages * p


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               layout: str | None = None, h2eal_on: bool = True):
    """Lower + compile one cell; returns stats dict."""
    import dataclasses

    from repro.configs.base import H2ealConfig

    cfg = get_arch(arch)
    if not h2eal_on:
        cfg = dataclasses.replace(
            cfg, h2eal=dataclasses.replace(cfg.h2eal, enabled=False))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    from repro.runtime.hints import set_sp_residual, sharding_hints
    # per-workload strategy selection (each measured; EXPERIMENTS.md §Perf):
    #  * sequence-parallel residual/attention: always for inference
    #    (forward-only — SP prefill is 30-60x cheaper); for training only
    #    when heads don't divide the model axis (otherwise dk/dv
    #    partial-sums in backward cost more than GSPMD's native TP plan)
    #  * ZeRO-3 use-constraints: off for MoE training (expert dispatch +
    #    per-microbatch regathers underperform GSPMD's default plan there)
    set_sp_residual(shape.kind != "train"
                    or cfg.num_heads % mesh.shape["model"] != 0)
    hints_on = not (shape.kind == "train" and cfg.moe.enabled)
    with mesh, sharding_hints(hints_on):
        if shape.kind == "train":
            params = S.param_specs(cfg, dtype=jnp.float32)
            batch = S.train_specs(cfg, shape)
            tcfg = _train_tcfg(cfg)
            opt = {
                "mu": jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32),
                    params),
                "nu": jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32),
                    params),
                "count": jax.ShapeDtypeStruct((), jnp.int32),
            }
            step_fn = train_rt.jit_train_step(
                cfg, tcfg, mesh, params, opt, shape.global_batch)
            lowered = step_fn.lower(
                params, opt, batch, jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            params = S.param_specs(cfg, dtype=jnp.float32)
            batch = S.prefill_specs(cfg, shape, dtype=jnp.float32)
            scfg = serve_rt.ServeConfig(
                capacity=_round_capacity(cfg, shape.seq_len + 64, mesh),
                layout=layout)
            state = jax.eval_shape(
                serve_rt.make_prefill(cfg, scfg), params, batch)[1]
            prefill, _, _ = serve_rt.jit_serve_steps(
                cfg, scfg, mesh, params, state, shape.global_batch)
            lowered = prefill.lower(params, batch)
        else:  # decode
            params = S.param_specs(cfg, dtype=jnp.float32)
            batch = S.prefill_specs(cfg, shape, dtype=jnp.float32)
            scfg = serve_rt.ServeConfig(
                capacity=_round_capacity(cfg, shape.seq_len + 64, mesh),
                layout=layout)
            state = jax.eval_shape(
                serve_rt.make_prefill(cfg, scfg), params, batch)[1]
            _, dec_sel, _ = serve_rt.jit_serve_steps(
                cfg, scfg, mesh, params, state, shape.global_batch)
            token = S.decode_token_specs(cfg, shape, dtype=jnp.float32)
            lowered = dec_sel.lower(params, state, token)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    # trip-corrected accounting: XLA's cost_analysis counts while bodies
    # ONCE; our programs scan over layers/microbatches, so dot FLOPs and
    # collectives are re-counted from the HLO with known_trip_count
    # multiplication (hlo_stats.computation_multiplicities).
    coll = hlo_stats.collective_stats_with_trips(hlo)
    cost = hlo_stats.cost_stats(compiled)  # raw (uncorrected) diagnostics
    cost["flops_raw_body_once"] = cost.get("flops", 0.0)
    cost["flops"] = hlo_stats.flops_with_trips(hlo)
    mem = hlo_stats.memory_stats(compiled)
    chips = mesh.devices.size

    # roofline terms (seconds). all per-device (post-SPMD);
    # data-proportional terms scaled to the bf16 production wire format.
    compute_s = cost.get("flops", 0.0) / PEAK_FLOPS

    # memory term: analytical byte model (see runtime/perfmodel.py for why
    # XLA's gather/fusion byte charging is unusable for paged decode);
    # the raw HLO number stays in cost["bytes"] as a diagnostic.
    from repro.runtime import perfmodel
    mm = perfmodel.MeshModel(
        chips=int(chips),
        data=mesh.shape["data"] * mesh.shape.get("pod", 1),
        model=mesh.shape["model"])
    eff_layout = layout or (
        "interleave" if shape.global_batch < mm.data else "head")
    model_bytes = perfmodel.cell_bytes(
        cfg, shape, mm, layout=eff_layout,
        microbatches=_train_tcfg(cfg).microbatches)
    cost["bytes_model"] = model_bytes["total"]
    memory_s = model_bytes["total"] / HBM_BW
    coll_s = coll.get("total_bytes", 0) * BF16_WIRE_FACTOR / ICI_BW
    model_flops = 6 * cfg.active_param_count() * (
        shape.global_batch * shape.seq_len if shape.kind == "train"
        else (shape.global_batch if shape.kind == "decode"
              else shape.global_batch * shape.seq_len))
    if shape.kind == "train":
        model_flops = model_flops  # fwd+bwd ≈ 6ND already
    else:
        model_flops = model_flops / 3  # inference: 2ND
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "chips": int(chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost": cost,
        "collectives": coll,
        "memory": mem,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": max(
                (("compute", compute_s), ("memory", memory_s),
                 ("collective", coll_s)), key=lambda kv: kv[1])[0],
        },
        "model_flops_global": model_flops,
        "hlo_flops_global": cost.get("flops", 0.0) * chips,
        "bytes_breakdown": {k: float(v) for k, v in model_bytes.items()},
        "layout": eff_layout if shape.kind == "decode" else None,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"],
                    default="off")
    ap.add_argument("--layout", default=None,
                    choices=[None, "head", "coplace", "interleave"])
    ap.add_argument("--h2eal", choices=["on", "off"], default="on")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(ASSIGNED) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"on": [True], "off": [False], "both": [False, True]}[
        args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = lower_cell(arch, shape, multi_pod=mp,
                                   layout=args.layout,
                                   h2eal_on=args.h2eal == "on")
                    rl = r["roofline"]
                    print(f"[ok] {tag}: compile={r['compile_s']}s "
                          f"compute={rl['compute_s']:.3e}s "
                          f"mem={rl['memory_s']:.3e}s "
                          f"coll={rl['collective_s']:.3e}s "
                          f"dominant={rl['dominant']}", flush=True)
                    results.append(r)
                except Exception as e:
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "error": str(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(1 for r in results if "error" in r)
    print(f"{len(results) - n_fail}/{len(results)} cells OK")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
