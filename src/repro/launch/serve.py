"""Serving driver: batched prefill + decode with H²EAL sparse attention.

Realizes the paper's serving loop: page selection runs every
``share_window`` steps (the `select` compiled variant), cheaper `reuse`
steps in between. Greedy sampling.

CPU demo (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --reduced --prompt-len 96 --gen 32 --batch 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.runtime import serve as serve_rt


def generate(cfg, params, prompts, *, gen: int, capacity: int,
             mesh=None, layout=None, h2eal=True, greedy=True):
    """prompts: (B, S) int32. Returns (tokens (B, gen), stats dict)."""
    import dataclasses

    if not h2eal:
        cfg = dataclasses.replace(
            cfg, h2eal=dataclasses.replace(cfg.h2eal, enabled=False))
    scfg = serve_rt.ServeConfig(capacity=capacity, layout=layout)
    b = prompts.shape[0]
    if mesh is not None:
        params_s = params
        state = jax.eval_shape(
            serve_rt.make_prefill(cfg, scfg), params, prompts)[1]
        prefill, dec_sel, dec_reuse = serve_rt.jit_serve_steps(
            cfg, scfg, mesh, params_s, state, b)
    else:
        prefill = jax.jit(serve_rt.make_prefill(cfg, scfg))
        dec_sel = jax.jit(serve_rt.make_decode_step(cfg, scfg,
                                                    do_select=True))
        dec_reuse = jax.jit(serve_rt.make_decode_step(cfg, scfg,
                                                      do_select=False))

    t0 = time.time()
    logits, state = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    w = max(cfg.h2eal.share_window, 1)
    outs = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(gen):
        outs.append(tok)
        fn = dec_sel if (i % w == 0) else dec_reuse
        logits, state = fn(params, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    stats = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": b * gen / t_decode if t_decode > 0 else float("inf"),
    }
    return jnp.stack(outs, axis=1), stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--h2eal", choices=["on", "off"], default="on")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    toks, stats = generate(
        cfg, params, prompts, gen=args.gen,
        capacity=args.prompt_len + args.gen + cfg.h2eal.page_size,
        h2eal=args.h2eal == "on")
    print(f"[serve] arch={cfg.name} b={args.batch} "
          f"prefill={stats['prefill_s']:.2f}s "
          f"decode={stats['decode_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s)")
    print(f"[serve] sample tokens: {toks[0, :16].tolist()}")
    return stats


if __name__ == "__main__":
    main()
