"""Serving driver: lockstep batches or continuous batching.

Two workload modes:

``--workload uniform`` (the original driver): one fixed batch, every
request shares one prompt length and one generation length. Page
selection runs every ``share_window`` steps (the `select` compiled
variant), cheaper `reuse` steps in between. Greedy sampling.

``--workload ragged``: slot-based continuous batching via
``repro.serving.Engine``. Requests draw prompt lengths from a small set
of buckets and generation lengths from [gen-min, gen-max]; the engine
admits them into free slots of a fixed max-batch compiled shape,
retires finished slots without recompiling, and keeps per-slot
share-window selection cadence. ``--prefill-chunk N`` switches
admission from prefill-then-pack to chunked slot-resident prefill: at
most N prompt tokens per engine step stream directly into the slot's
(possibly sharded) caches, interleaved with decode — bounded
time-to-first-token on long prompts (docs/serving.md). Reports
throughput, batch occupancy, admissions/chunk counts, per-function jit
compile counts, and (with ``--report-balance``) the sched/balance
imbalance score of the final ragged batch on a 4x4 bank grid.

``--layout`` accepts any core/layouts registry entry:
``coplace_shmap`` runs the ragged workload under shard_map
memory-compute co-placement on a host-local mesh (pages sharded over the
'model' axis; paper §IV-B), ``interleave`` under GSPMD within-page token
striping (paper Fig 7b); ``--admission balanced`` adds the
balance-aware admission order (sched/balance.admission_score) for any
page-sharding layout.
``--attn-impl pallas`` swaps the attention bodies for the Pallas kernels
(kernels/ops.py dispatch; interpret mode off-TPU) — including the
partial-attention + fused-combine pair inside the coplace_shmap decode.
The impl is fixed at engine construction, never switched per step.
``--rebalance retire|interval`` arms live slot migration
(sched/cost.py + sched/rebalance.py): the engine re-plans slot
placement when retirements skew the per-bank compute and moves cache
rows between slot indices without recompiling or changing any token
(docs/serving.md §Rebalancing).
``--decode-window w`` fuses up to ``w`` reuse steps between selection
boundaries into ONE dispatched lax.scan with in-scan sampling and
device-side retirement (docs/serving.md §Fused decode windows); token
traces stay bit-exact vs per-step dispatch.

CPU demo (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --reduced --prompt-len 96 --gen 32 --batch 2
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --reduced --workload ragged --requests 8 --max-batch 4 \
      --prompt-buckets 32,64 --gen-min 4 --gen-max 24
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --reduced --workload ragged --layout coplace_shmap \
      --admission balanced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.runtime import serve as serve_rt


def generate(cfg, params, prompts, *, gen: int, capacity: int,
             mesh=None, layout="default", h2eal=True, greedy=True,
             attn_impl: str = "ref"):
    """Lockstep generation. prompts: (B, S) int32.
    Returns (tokens (B, gen), stats dict)."""
    import dataclasses

    if not h2eal:
        cfg = dataclasses.replace(
            cfg, h2eal=dataclasses.replace(cfg.h2eal, enabled=False))
    scfg = serve_rt.ServeConfig(capacity=capacity, layout=layout,
                                impl=attn_impl)
    b = prompts.shape[0]
    if mesh is not None:
        params_s = params
        state = jax.eval_shape(
            serve_rt.make_prefill(cfg, scfg), params, prompts)[1]
        prefill, dec_sel, dec_reuse = serve_rt.jit_serve_steps(
            cfg, scfg, mesh, params_s, state, b)
    else:
        prefill = jax.jit(serve_rt.make_prefill(cfg, scfg))
        dec_sel = jax.jit(serve_rt.make_decode_step(cfg, scfg,
                                                    do_select=True))
        dec_reuse = jax.jit(serve_rt.make_decode_step(cfg, scfg,
                                                      do_select=False))

    t0 = time.time()
    logits, state = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    w = max(cfg.h2eal.share_window, 1)
    outs = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(gen):
        outs.append(tok)
        fn = dec_sel if (i % w == 0) else dec_reuse
        logits, state = fn(params, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    stats = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": b * gen / t_decode if t_decode > 0 else float("inf"),
    }
    return jnp.stack(outs, axis=1), stats


def make_ragged_requests(cfg, *, n: int, prompt_buckets, gen_min: int,
                         gen_max: int, seed: int = 0):
    """Seeded ragged workload: bucketed prompt lengths, variable gen."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        s = int(rng.choice(prompt_buckets))
        g = int(rng.integers(gen_min, gen_max + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=(s,)).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=g))
    return reqs


def run_ragged(cfg, params, requests, *, max_batch: int, capacity: int,
               prompt_buckets, report_balance: bool = False,
               layout="default", admission: str = "fifo",
               attn_impl: str = "ref", prefill_chunk=None,
               rebalance: str = "off", decode_window=None):
    """Serve ``requests`` with the continuous-batching engine.

    ``layout`` is any core/layouts registry entry (e.g. "coplace_shmap"
    builds a host-local mesh with every device on the 'model' axis and
    runs the sharded partial-attention decode; "interleave" stripes
    within-page tokens over the 'data' axis under GSPMD);
    ``attn_impl="pallas"`` swaps the decode body for the Pallas kernels
    (interpret mode off-TPU) — fixed at engine construction, never per
    step. ``prefill_chunk=N`` switches admission from prefill-then-pack
    to chunked slot-resident prefill (≤ N prompt tokens per engine step,
    interleaved with decode — docs/serving.md). ``rebalance`` arms the
    live slot-migration planner (sched/rebalance.py): "retire" re-plans
    when a retirement frees a slot, "interval" every
    ``rebalance_interval`` steps — token traces are bit-exact either way
    (docs/serving.md §Rebalancing). ``decode_window=w`` fuses up to w
    reuse steps per dispatch with device-side retirement
    (docs/serving.md §Fused decode windows).
    Returns (completions, stats dict)."""
    from repro.core import layouts as layoutlib
    from repro.serving import Engine

    if admission == "balanced" and \
            not layoutlib.get_layout(layout).shards_pages:
        raise ValueError(
            "--admission balanced scores per-device page load and only has "
            "an effect for layouts that shard pages (e.g. --layout "
            "coplace_shmap or interleave)")
    eng = Engine(cfg, params, max_batch=max_batch, capacity=capacity,
                 prompt_buckets=prompt_buckets, layout=layout,
                 admission=admission, impl=attn_impl,
                 prefill_chunk=prefill_chunk, rebalance=rebalance,
                 decode_window=decode_window)
    completions = eng.run(requests)
    s = eng.stats
    stats = {
        "wall_s": s.wall_s,
        "tokens_per_s": s.tokens_per_s,
        "decode_steps": s.decode_steps,
        "engine_steps": s.engine_steps,
        "select_steps": s.select_steps,
        "reuse_steps": s.reuse_steps,
        "admissions": s.admissions,
        "prefill_chunks": s.prefill_chunks,
        "occupancy": s.occupancy,
        "tokens_out": s.tokens_out,
        "admission_reorders": s.admission_reorders,
        "dispatches": s.dispatches,
        "steps_per_dispatch": s.steps_per_dispatch,
        "jit_cache": eng.jit_cache_sizes(),
    }
    if decode_window:
        stats["fused"] = {
            "decode_window": decode_window,
            "fused_windows": s.fused_windows,
            "fused_steps": s.fused_steps,
        }
    if rebalance != "off":
        stats["rebalance"] = {
            "trigger": rebalance,
            "checks": s.rebalance_checks,
            "rebalances": s.rebalances,
            "skipped": s.rebalance_skipped,
            "migrations": s.migrations,
            "migrated_tokens": s.migrated_tokens,
            "imbalance_pre": s.imbalance_pre,
            "imbalance_post": s.imbalance_post,
        }
    if report_balance:
        stats["balance"] = _balance_report(cfg, eng)
    return completions, stats


def _balance_report(cfg, eng):
    """Score the engine's current/last ragged batch with the paper's
    tiling + co-placement load split on a 4x4 bank grid, plus the sharded
    page-load view (device_page_loads), the whole-slot LPT placement
    (map_slots) the balanced admission policy optimizes against, and the
    rebalancer's own per-bank cost-model view (sched/cost.py via
    Engine.compute_loads) with its migration counters."""
    from repro.sched import (device_page_loads, grid_coords, imbalance,
                             load_imbalance, map_slots, ragged_loads,
                             slot_head_load, solve_tiling)

    ctx = [int(c) for c in eng.batch.lengths if c > 0]
    s = eng.stats
    base = {"admissions": s.admissions, "prefill_chunks": s.prefill_chunks}
    loads = eng.compute_loads()
    if loads:
        base["cost_loads"] = [round(x, 1) for x in loads]
        base["cost_imbalance"] = load_imbalance(loads)
    if eng.rebalance != "off":
        base.update(migrations=s.migrations, rebalances=s.rebalances,
                    imbalance_pre=s.imbalance_pre,
                    imbalance_post=s.imbalance_post)
    if not ctx:
        return base
    coords = grid_coords(4, 4)[: cfg.num_kv_heads]
    spec_nr = max(cfg.num_kv_heads
                  - round(cfg.num_kv_heads * cfg.h2eal.static_sparsity), 0)
    retr, stream = coords[:spec_nr], coords[spec_nr:]
    tiles, _ = solve_tiling(retr, stream)
    kinds = {c: ("retrieval" if c in retr else "streaming") for c in coords}
    u = ragged_loads(tiles, kinds, cfg.h2eal, ctx, balanced=False)
    b = ragged_loads(tiles, kinds, cfg.h2eal, ctx, balanced=True)
    n_sh = (int(eng.mesh.shape["model"])
            if eng.mesh is not None and "model" in eng.mesh.axis_names
            else 4)
    pages = device_page_loads(ctx, n_shards=max(n_sh, 1),
                              page_size=cfg.h2eal.page_size)
    lpt = map_slots([slot_head_load("retrieval", cfg.h2eal, c) for c in ctx],
                    max(n_sh, 1))
    return dict(base,
                imbalance_naive=imbalance(u),
                imbalance_coplaced=imbalance(b),
                page_load_imbalance=load_imbalance(pages),
                slot_lpt_imbalance=lpt.imbalance)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--workload", choices=["uniform", "ragged"],
                    default="uniform")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--h2eal", choices=["on", "off"], default="on")
    ap.add_argument("--seed", type=int, default=0)
    # ragged-workload knobs
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-buckets", default="32,64",
                    help="comma-separated allowed prompt lengths")
    ap.add_argument("--gen-min", type=int, default=4)
    ap.add_argument("--gen-max", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=0,
                    help="cache capacity in tokens (0 = auto)")
    ap.add_argument("--report-balance", action="store_true")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked slot-resident prefill: feed at most N "
                         "prompt tokens per engine step, interleaved with "
                         "decode (bounded TTFT, no head-of-line blocking "
                         "on long prompts). 0 = prefill-then-pack "
                         "admission (docs/serving.md)")
    from repro.core.layouts import available_layouts
    ap.add_argument("--layout",
                    choices=["auto"] + list(available_layouts()),
                    default="default",
                    help="serve-cache layout (ragged workload), a "
                         "core/layouts registry entry ('auto' is a "
                         "deprecated alias for default). "
                         "coplace_shmap = shard_map co-placement, "
                         "interleave = GSPMD within-page token striping, "
                         "both on a host-local mesh")
    ap.add_argument("--admission", choices=["fifo", "balanced"],
                    default="fifo",
                    help="ragged admission order (balanced = per-device "
                         "page-load aware, sched/balance.py)")
    ap.add_argument("--rebalance", choices=["off", "retire", "interval"],
                    default="off",
                    help="live slot-migration trigger (sched/rebalance.py): "
                         "retire = re-plan when a retirement frees a slot, "
                         "interval = every 16 engine steps. Token traces "
                         "stay bit-exact (docs/serving.md §Rebalancing)")
    ap.add_argument("--decode-window", type=int, default=0,
                    help="fuse up to N reuse steps between selection "
                         "boundaries into one dispatched scan with "
                         "device-side retirement (0 = per-step dispatch; "
                         "docs/serving.md §Fused decode windows)")
    ap.add_argument("--share-window", type=int, default=0,
                    help="override cfg.h2eal.share_window (selection "
                         "cadence). The reduced configs pin it to 2, "
                         "leaving a single reuse step per window; widen "
                         "it to give --decode-window room to fuse")
    ap.add_argument("--attn-impl", choices=["ref", "pallas"], default="ref",
                    help="attention kernel impl (kernels/ops.py): ref = "
                         "pure-jnp oracle, pallas = Pallas kernels "
                         "(interpret mode off-TPU). Fixed at engine "
                         "construction; see docs/serving.md")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.share_window:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, h2eal=dataclasses.replace(cfg.h2eal,
                                           share_window=args.share_window))
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)

    if args.workload == "ragged":
        buckets = [int(x) for x in args.prompt_buckets.split(",")]
        capacity = args.capacity or (
            max(buckets) + args.gen_max + cfg.h2eal.page_size)
        reqs = make_ragged_requests(
            cfg, n=args.requests, prompt_buckets=buckets,
            gen_min=args.gen_min, gen_max=args.gen_max, seed=args.seed)
        completions, stats = run_ragged(
            cfg, params, reqs, max_batch=args.max_batch, capacity=capacity,
            prompt_buckets=buckets, report_balance=args.report_balance,
            layout=args.layout, admission=args.admission,
            attn_impl=args.attn_impl,
            prefill_chunk=args.prefill_chunk or None,
            rebalance=args.rebalance,
            decode_window=args.decode_window or None)
        print(f"[serve] arch={cfg.name} workload=ragged "
              f"layout={args.layout} admission={args.admission} "
              f"attn_impl={args.attn_impl} rebalance={args.rebalance} "
              f"prefill_chunk={args.prefill_chunk or 'packed'} "
              f"requests={len(completions)} steps={stats['decode_steps']} "
              f"occupancy={stats['occupancy']:.2f} "
              f"({stats['tokens_per_s']:.1f} tok/s)")
        print(f"[serve] select/reuse steps: {stats['select_steps']}/"
              f"{stats['reuse_steps']}; admissions/chunks: "
              f"{stats['admissions']}/{stats['prefill_chunks']}; "
              f"admission reorders: {stats['admission_reorders']}; "
              f"jit compiles: {stats['jit_cache']}")
        if "fused" in stats:
            fu = stats["fused"]
            print(f"[serve] fused decode windows: w={fu['decode_window']} "
                  f"windows={fu['fused_windows']} "
                  f"fused_steps={fu['fused_steps']} "
                  f"dispatches={stats['dispatches']} "
                  f"steps/dispatch={stats['steps_per_dispatch']:.2f}")
        if "rebalance" in stats:
            r = stats["rebalance"]
            print(f"[serve] rebalance trigger={r['trigger']} "
                  f"checks={r['checks']} applied={r['rebalances']} "
                  f"skipped={r['skipped']} migrations={r['migrations']} "
                  f"imbalance {r['imbalance_pre']:.3f} -> "
                  f"{r['imbalance_post']:.3f}")
        if "balance" in stats and stats["balance"]:
            bal = stats["balance"]
            if "imbalance_naive" in bal:
                print(f"[serve] bank imbalance naive="
                      f"{bal['imbalance_naive']:.2f} "
                      f"coplaced={bal['imbalance_coplaced']:.2f} "
                      f"page_load={bal['page_load_imbalance']:.2f} "
                      f"slot_lpt={bal['slot_lpt_imbalance']:.2f}")
            if "cost_imbalance" in bal:
                print(f"[serve] cost-model bank loads "
                      f"{bal['cost_loads']} "
                      f"(imbalance {bal['cost_imbalance']:.2f})")
        if completions:
            some = completions[min(completions)]
            print(f"[serve] sample tokens (uid {some.uid}): "
                  f"{some.tokens[:16]}")
        return stats

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    toks, stats = generate(
        cfg, params, prompts, gen=args.gen,
        capacity=args.prompt_len + args.gen + cfg.h2eal.page_size,
        h2eal=args.h2eal == "on", attn_impl=args.attn_impl)
    print(f"[serve] arch={cfg.name} b={args.batch} "
          f"prefill={stats['prefill_s']:.2f}s "
          f"decode={stats['decode_s']:.2f}s "
          f"({stats['tokens_per_s']:.1f} tok/s)")
    print(f"[serve] sample tokens: {toks[0, :16].tolist()}")
    return stats


if __name__ == "__main__":
    main()
