"""ShapeDtypeStruct stand-ins for every model input — no allocation.

``input_specs(arch, shape)`` returns what the lowered step functions take:
  train:   {"tokens": (B,S), "labels": (B,S)}   (embeds for stub archs)
  prefill: batch (B,S) (or embeds)
  decode:  token (B,) (or (B, F)) — the serve state comes from
           jax.eval_shape(prefill) (see dryrun.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    if cfg.embed_frontend_stub:
        batch = SDS((b, s, cfg.frontend_dim), dtype)
    else:
        batch = SDS((b, s), jnp.int32)
    return {"tokens": batch, "labels": SDS((b, s), jnp.int32)}


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    if cfg.embed_frontend_stub:
        return SDS((b, s, cfg.frontend_dim), dtype)
    return SDS((b, s), jnp.int32)


def decode_token_specs(cfg: ArchConfig, shape: ShapeConfig,
                       dtype=jnp.bfloat16):
    b = shape.global_batch
    if cfg.embed_frontend_stub:
        return SDS((b, cfg.frontend_dim), dtype)
    return SDS((b,), jnp.int32)


def param_specs(cfg: ArchConfig, dtype=jnp.bfloat16):
    from repro.models import model as M

    return jax.eval_shape(
        lambda k: M.init_params(cfg, k, dtype=dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
