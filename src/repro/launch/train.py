"""Training driver with fault tolerance.

Features:
  * resumes from the latest checkpoint (step-atomic; data stream is
    seekable by step so the token sequence is bit-identical across
    restarts);
  * per-step watchdog — a step exceeding ``--watchdog`` seconds logs a
    straggler warning (on a real cluster this triggers requeue/replace;
    here it is surfaced and counted);
  * elastic: restoring onto a different mesh shape reshards automatically
    (checkpoint stores logical arrays; device_put applies new shardings);
  * crash-injection hook (--crash-at) used by the integration test to
    prove restart-exactness.

CPU demo (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import ckpt
from repro.configs import get_arch, reduced
from repro.data import lm_batch
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.runtime import sharding as shardlib
from repro.runtime import train as train_rt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--watchdog", type=float, default=120.0,
                    help="straggler threshold (s/step)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="raise after N steps (fault-tolerance test)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_local_mesh()
    tcfg = train_rt.TrainConfig(
        microbatches=args.microbatches, remat=True, lr=args.lr,
        total_steps=args.steps)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    opt_state = adamw.init_state(params)
    start_step = 0

    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree = {"params": params, "opt": opt_state}
        shardings = {
            "params": shardlib.param_shardings(cfg, mesh, params),
            "opt": {"mu": shardlib.param_shardings(cfg, mesh, params),
                    "nu": shardlib.param_shardings(cfg, mesh, params),
                    "count": None},
        }
        restored, meta = ckpt.restore(args.ckpt_dir, tree)
        params, opt_state = restored["params"], restored["opt"]
        start_step = int(meta["step"]) + 1
        print(f"[train] resumed from step {meta['step']} "
              f"(elastic mesh {tuple(mesh.shape.values())})")

    step_fn = train_rt.jit_train_step(cfg, tcfg, mesh, params, opt_state,
                                      args.batch)

    stragglers = 0
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = lm_batch(jnp.int32(step), batch=args.batch, seq=args.seq,
                         vocab=cfg.vocab_size, seed=args.seed)
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.int32(step))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if dt > args.watchdog:
            stragglers += 1
            print(f"[train] WARNING step {step} straggled: {dt:.1f}s")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, {"params": params, "opt": opt_state},
                      step=step, metadata={"step": step, "seed": args.seed})
            ckpt.prune_old(args.ckpt_dir, keep=2)
        if args.crash_at is not None and step + 1 >= args.crash_at:
            raise RuntimeError(f"injected crash at step {step}")
    print(f"[train] done: {args.steps} steps, {stragglers} stragglers, "
          f"final loss {loss:.4f}")
    return loss


if __name__ == "__main__":
    main()
