"""Production mesh construction.

A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init; tests and
benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
