"""Distributed training step builder (pjit).

Features for the 1000+-node posture:
  * microbatched gradient accumulation (scan) — the per-microbatch psum
    overlaps the next microbatch's compute under XLA's async collectives;
  * remat per layer-period (jax.checkpoint inside the model scan);
  * bf16 gradient reduction option (half the DP all-reduce bytes);
  * optimizer state sharded like the params (ZeRO via the 'data' dim of
    the 2D param sharding).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim import adamw
from repro.runtime import sharding as shardlib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool = True
    grad_dtype: str = "f32"       # "f32" | "bf16"
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    impl: str = "ref"


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics). Pure; jit/pjit-ready."""
    ocfg = adamw.AdamWConfig(lr=tcfg.lr)

    def loss_fn(params, tokens, labels):
        loss = M.lm_loss(cfg, params, tokens, labels, impl=tcfg.impl,
                         remat=tcfg.remat)
        return loss

    def train_step(params, opt_state, batch, step):
        tokens, labels = batch["tokens"], batch["labels"]
        mb = tcfg.microbatches
        if mb > 1:
            b = tokens.shape[0]
            tk = tokens.reshape(mb, b // mb, *tokens.shape[1:])
            lb = labels.reshape(mb, b // mb, *labels.shape[1:])

            def micro(acc, xs):
                t, l = xs
                loss, g = jax.value_and_grad(loss_fn)(params, t, l)
                if tcfg.grad_dtype == "bf16":
                    g = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + loss), ()

            zero = (jax.tree.map(
                lambda p: jnp.zeros(p.shape,
                                    jnp.bfloat16 if tcfg.grad_dtype == "bf16"
                                    else jnp.float32), params),
                jnp.float32(0))
            (grads, loss_sum), _ = jax.lax.scan(micro, zero, (tk, lb))
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / mb, grads)
            loss = loss_sum / mb
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
            if tcfg.grad_dtype == "bf16":
                grads = jax.tree.map(
                    lambda x: x.astype(jnp.bfloat16).astype(jnp.float32),
                    grads)
        lr_scale = adamw.cosine_schedule(
            step, warmup=tcfg.warmup, total=tcfg.total_steps)
        params2, opt_state2, gnorm = adamw.apply_updates(
            params, grads, opt_state, ocfg, lr_scale=lr_scale)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr_scale": lr_scale}
        return params2, opt_state2, metrics

    return train_step


def jit_train_step(cfg: ArchConfig, tcfg: TrainConfig, mesh: Mesh, params,
                   opt_state, batch_size: int):
    """jit with explicit in/out shardings for the dry-run and real runs."""
    ps = shardlib.param_shardings(cfg, mesh, params, mode="train")
    pso = shardlib.param_shardings(cfg, mesh, params, mode="opt")
    os_ = {"mu": pso, "nu": pso,
           "count": NamedSharding(mesh, P())}
    bs = shardlib.batch_sharding(mesh, batch_size)
    batch_sh = {"tokens": bs, "labels": bs}
    scalar = NamedSharding(mesh, P())
    step_fn = make_train_step(cfg, tcfg)
    return jax.jit(
        step_fn,
        in_shardings=(ps, os_, batch_sh, scalar),
        out_shardings=(ps, os_, {"loss": scalar, "grad_norm": scalar,
                                 "lr_scale": scalar}),
        donate_argnums=(0, 1),
    )
