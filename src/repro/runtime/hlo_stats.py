"""Post-partitioning HLO statistics for the roofline analysis.

collective_bytes: parsed from ``compiled.as_text()`` — sums the result
sizes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (async ``-start`` variants counted once, ``-done``
skipped). Result size is the wire-visible payload per device; ring-factor
adjustments (×2 for all-reduce, ×(n-1)/n for gather/scatter) are applied
in the roofline model, not here.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9_,\[\]{}\s/#*]+?\)?)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?P<variant>-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Returns {op: {"count": int, "bytes": int}} + {"total_bytes": int}."""
    stats: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("variant") == "-done":
            continue
        op = m.group("op")
        b = _type_bytes(m.group("type"))
        stats[op]["count"] += 1
        stats[op]["bytes"] += b
    out = {k: dict(v) for k, v in stats.items()}
    out["total_bytes"] = sum(v["bytes"] for v in stats.values())
    return out


_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.+)$")
_RESULT_TYPE_RE = re.compile(
    r"^(\(?(?:[a-z][a-z0-9]*\[[0-9,]*\][^\s,)]*(?:,\s*)?)+\)?)")
_CONVERT_FUSION = re.compile(r"calls=%?\w*convert\w*")


def convert_overhead_bytes(hlo_text: str) -> int:
    """Traffic of large cross-precision converts (CPU float normalization;
    absent on TPU where bf16 is MXU-native). XLA:CPU upcasts bf16 compute
    to f32 and hoists the converts out of loops, charging whole caches at
    3x their real size — this returns those bytes so the roofline memory
    term can be corrected. Only MB-scale converts are counted."""
    defs: dict = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        tm = _RESULT_TYPE_RE.match(rest)
        if tm:
            defs[name] = _type_bytes(tm.group(1))
    total = 0
    scope = ""
    comp_hdr = re.compile(r"^\s*%?([\w.-]+)\s+\([^)]*")
    for line in hlo_text.splitlines():
        s = line.rstrip()
        if s.endswith("{") and "=" not in s.split("{")[0]:
            m = comp_hdr.match(s)
            scope = m.group(1) if m else ""
            continue
        if s == "}":
            scope = ""
            continue
        # skip instruction lines inside fusion bodies: their converts are
        # accounted through the fusion call line instead
        in_fusion_body = "computation" in scope
        is_conv = " convert(" in line and not in_fusion_body
        is_conv_fusion = "fusion(" in line and _CONVERT_FUSION.search(line)
        if not (is_conv or is_conv_fusion):
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        out_b = defs.get(name, 0)
        args = re.search(r"(?:convert|fusion)\(([^)]*)\)", rest)
        in_b = (sum(defs.get(r, 0)
                    for r in re.findall(r"%([\w.-]+)", args.group(1)))
                if args else 0)
        if out_b >= 1 << 20:
            total += out_b + in_b
    return total


def _parse_computations(hlo_text: str):
    """{comp_name: [(opcode, out_bytes, [operand_names...], raw_line)]}.

    Also returns defs: {instr_name: out_bytes} and shapes:
    {instr_name: [(dtype, dims), ...]} and the ENTRY computation name.
    """
    comps: dict = {}
    defs: dict = {}
    shapes: dict = {}
    entry = None
    scope = None
    pending_hdr = None  # (name, is_entry) of a header wrapping over lines
    comp_hdr = re.compile(r"^\s*(ENTRY\s+)?%?([\w.$-]+)\s+\(")
    op_re = re.compile(r"\]\S*\s+([a-z][a-z0-9-]*)\(")
    for line in hlo_text.splitlines():
        s = line.rstrip()
        if pending_hdr is not None:
            if s.endswith("{"):
                scope, is_entry = pending_hdr
                comps.setdefault(scope, [])
                if is_entry:
                    entry = scope
                pending_hdr = None
            continue
        # computation headers have no " = " before the param list and may
        # wrap across many lines when the parameter tuple is long
        if " = " not in s.split("(")[0]:
            m = comp_hdr.match(s)
            if m and "=" not in s[: m.end()]:
                if s.endswith("{"):
                    scope = m.group(2)
                    comps.setdefault(scope, [])
                    if m.group(1):
                        entry = scope
                else:
                    pending_hdr = (m.group(2), bool(m.group(1)))
                continue
        if s == "}":
            scope = None
            continue
        m = _LINE_RE.match(line)
        if not m or scope is None:
            continue
        name, rest = m.groups()
        tm = _RESULT_TYPE_RE.match(rest)
        out_b = _type_bytes(tm.group(1)) if tm else 0
        defs[name] = out_b
        if tm:
            shapes[name] = [
                (dt, [int(x) for x in dims.split(",") if x])
                for dt, dims in _SHAPE_RE.findall(tm.group(1))]
        om = op_re.search(rest)
        opcode = om.group(1) if om else ""
        args = re.search(r"\(([^)]*)\)", rest[rest.find(opcode):] if opcode
                         else "")
        ops = (re.findall(r"%([\w.-]+)", args.group(1)) if args else [])
        comps[scope].append((opcode, out_b, ops, rest))
    return comps, defs, shapes, entry


_TRIP_RE = re.compile(r'known_trip_count[^0-9]{0,10}(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.-]+)")
_BODY_RE = re.compile(r"body=%?([\w.-]+)")
_COND_RE = re.compile(r"condition=%?([\w.-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w.-]+)")
_BRANCHES_RE = re.compile(
    r"(?:branch_computations|called_computations)=\{([^}]*)\}")


def computation_multiplicities(hlo_text: str):
    """Execution count of each computation, multiplying while-loop trip
    counts through the call graph (fusion/call/cond bodies inherit the
    caller's multiplicity)."""
    comps, defs, shapes, entry = _parse_computations(hlo_text)
    mult = {name: 0 for name in comps}
    if entry is None:
        # fall back: first computation
        entry = next(iter(comps), None)
    if entry is None:
        return comps, defs, shapes, {}
    # BFS accumulation
    pending = [(entry, 1)]
    while pending:
        name, m = pending.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0) + m
        for opcode, _, _, raw in comps[name]:
            children = []
            trip = 1
            if opcode == "while":
                t = _TRIP_RE.search(raw)
                trip = int(t.group(1)) if t else 1
                bm = _BODY_RE.search(raw)
                cm = _COND_RE.search(raw)
                if bm:
                    children.append(bm.group(1))
                if cm:
                    children.append(cm.group(1))
            else:
                for rex in (_CALLS_RE, _APPLY_RE):
                    mm = rex.search(raw)
                    if mm:
                        children.append(mm.group(1))
                bb = _BRANCHES_RE.search(raw)
                if bb:
                    children.extend(
                        re.findall(r"%?([\w.-]+)", bb.group(1)))
            for c in children:
                if c in comps:
                    pending.append((c, m * trip))
    return comps, defs, shapes, mult


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def flops_with_trips(hlo_text: str) -> float:
    """Total dot FLOPs with while-trip multiplication (XLA's own
    cost_analysis counts each loop body exactly once — useless for
    scan-over-layers programs)."""
    comps, defs, shapes, mult = computation_multiplicities(hlo_text)
    total = 0.0
    for name, instrs in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for opcode, _, ops, raw in instrs:
            if opcode != "dot":
                continue
            tm = _RESULT_TYPE_RE.match(raw)
            if not tm:
                continue
            out_shapes = _SHAPE_RE.findall(tm.group(1))
            out_elems = 1
            for _, dims in out_shapes:
                for d in dims.split(","):
                    if d:
                        out_elems *= int(d)
            # contraction size from the lhs operand's shape
            cm = _CONTRACT_RE.search(raw)
            k = 1
            if cm and ops:
                lhs = shapes.get(ops[0])
                if lhs:
                    dims = lhs[0][1]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            k *= dims[int(idx)]
            total += 2.0 * out_elems * k * m
    return total


def collective_stats_with_trips(hlo_text: str) -> dict:
    """Like collective_stats but multiplied by loop trip counts."""
    comps, defs, shapes, mult = computation_multiplicities(hlo_text)
    stats: dict = {}
    for name, instrs in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for opcode, out_b, ops, raw in instrs:
            base = None
            for c in _COLLECTIVES:
                if opcode == c or opcode == c + "-start":
                    base = c
                    break
            if base is None:
                continue
            d = stats.setdefault(base, {"count": 0, "bytes": 0})
            d["count"] += m
            d["bytes"] += out_b * m
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if k != "total_bytes")
    return stats


def gather_overhead_bytes(hlo_text: str) -> int:
    """XLA's cost model charges gather at FULL operand size; real hardware
    (and the paper's entire premise) touches only the gathered bytes. This
    returns sum over gathers of (operand - 2*output) bytes, multiplied by
    the enclosing while loop's known trip count, so diagnostics can show
    what a paged-attention DMA actually moves."""
    comps, defs, shapes, mult = computation_multiplicities(hlo_text)
    total = 0
    for name, instrs in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for opcode, out_b, ops, _ in instrs:
            if opcode != "gather":
                continue
            opnd = max((defs.get(o, 0) for o in ops), default=0)
            over = opnd - 2 * out_b
            if over > 0:
                total += over * m
    return total


def cost_stats(compiled) -> dict:
    """flops / bytes from compiled.cost_analysis(), tolerant of backends."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
