"""Sharding hints: ZeRO-3 semantics under GSPMD.

Problem (measured, see EXPERIMENTS.md §Perf): with weights STORED 2D-sharded
(FSDP 'data' on the contraction dim × TP 'model'), GSPMD's matmul strategy
sometimes all-gathers the ACTIVATIONS over the batch axis instead of the
(1000× smaller) weight shards — turning a 4k-token train step into 684 GB
of all-gather per device and replicating attention compute ~250×.

Fix: at every weight use site, constrain the weight to its TP-only spec
(P(None,'model') for (in,out) matrices, P('model',None) for (out,in), …).
GSPMD then materializes the storage→use transfer as a weight all-gather
over 'data' — exactly ZeRO-3 — and the matmul itself is a clean TP matmul
against batch-sharded activations. Activations are additionally pinned to
batch-over-('pod','data') at layer-period boundaries so propagation can
never drift back to replication.

Everything is gated on ``enabled()`` — tests and single-device runs see
plain JAX (constraints require an ambient mesh).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def enabled() -> bool:
    return getattr(_STATE, "on", False)


@contextlib.contextmanager
def sharding_hints(on: bool = True):
    prev = getattr(_STATE, "on", False)
    _STATE.on = on
    try:
        yield
    finally:
        _STATE.on = prev


def _axes():
    # the abstract mesh is only set in explicit-sharding mode (jax >= 0.5;
    # None under the pinned 0.4.x); inside a plain `with mesh:` context the
    # physical mesh lives in thread resources (constraints with bare
    # PartitionSpecs resolve against it)
    from repro.runtime.compat import get_abstract_mesh

    am = get_abstract_mesh()
    if am is not None and not am.empty:
        return am.axis_names
    try:
        from jax._src import mesh as _mesh_lib

        pm = _mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm.axis_names
    except Exception:
        pass
    return None


def current_mesh():
    """The ambient physical mesh, or None."""
    try:
        from jax._src import mesh as _mesh_lib

        pm = _mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


def constrain(x, spec: P):
    if not enabled():
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def batch_axes_spec():
    ax = _axes()
    if ax is None:
        return None
    return ("pod", "data") if "pod" in ax else ("data",)


_SP_RESIDUAL = threading.local()


def set_sp_residual(on: bool):
    """Enable Megatron-style sequence-parallel residuals + seq-par
    attention. On by default; turned off per-arch when attention heads
    divide the model axis (plain TP attention wins there)."""
    _SP_RESIDUAL.on = on


def sp_residual() -> bool:
    return getattr(_SP_RESIDUAL, "on", True)


def act(x):
    """Pin activations: batch over ('pod','data'), and for full-sequence
    (B, S, d) residuals also sequence over 'model' (Megatron-style
    sequence parallelism — norms are per-token, TP matmul outputs arrive
    as reduce-scatters instead of all-reduces)."""
    if not enabled():
        return x
    ba = batch_axes_spec()
    if ba is None:
        return x
    if x.ndim == 3 and sp_residual():
        spec = P(ba, "model", None)
    else:
        spec = P(ba, *([None] * (x.ndim - 1)))
    return constrain(x, spec)


def pin(x, *axes):
    """Generic pin: axes entries are 'batch' (→ ('pod','data')), a mesh
    axis name, or None. No-op when hints are off / no mesh."""
    if not enabled():
        return x
    ba = batch_axes_spec()
    if ba is None:
        return x
    resolved = tuple(ba if a == "batch" else a for a in axes)
    return constrain(x, P(*resolved))


def decode_qkv(x):
    """Decode-step q/k/v (B, H, D): batch over data, heads replicated —
    uneven head counts (8 kv heads on a 16-way model axis) must never leak
    into the KV cache's sharding, or GSPMD re-gathers the entire stacked
    cache at the scan boundary (measured: 86 GB/step on qwen2-72b)."""
    if not enabled():
        return x
    ba = batch_axes_spec()
    if ba is None:
        return x
    return constrain(x, P(ba, None, None))


def _model_size():
    m = current_mesh()
    if m is None or "model" not in m.axis_names:
        return None
    return int(m.shape["model"])


def attn_q_chunks(qc):
    """Attention sharding for the chunked prefill/train path.
    qc: (B, nq, CQ, H, D).

    * heads divide the model axis → classic head-parallel (Megatron)
      attention: psum-free forward AND backward.
    * otherwise → sequence-parallel attention (beyond-paper; the TPU
      answer to the paper's Challenge-3 head/bank mismatch): shard the
      within-chunk q rows over 'model' — balanced for ANY head count
      (15, 5, 3, ...), at the cost of dk/dv partial-sums in backward."""
    if not enabled():
        return qc
    ba = batch_axes_spec()
    if ba is None:
        return qc
    # NOTE: a head-parallel variant (heads→'model' when divisible) was
    # tried and REFUTED: it conflicts with the sequence-sharded residual
    # and GSPMD falls into involuntary full rematerialization (see
    # EXPERIMENTS.md §Perf cell 1, iteration 5).
    if not sp_residual():
        return qc  # divisible heads: GSPMD's own TP plan is psum-free
    return constrain(qc, P(ba, None, "model", None, None))


def attn_kv(kv):
    """K/V for the chunked path, GQA-expanded: (B, S, Hq, D). Replicated
    over 'model' under sequence-parallel attention (the all-gather is tiny
    next to the compute); untouched under plain TP."""
    if not enabled():
        return kv
    ba = batch_axes_spec()
    if ba is None:
        return kv
    if not sp_residual():
        return kv
    return constrain(kv, P(ba, None, None, None))


def attn_out(out):
    """Chunk outputs, sharded like q. out: (nq, B, CQ, H, D) (scan-stacked)."""
    if not enabled():
        return out
    ba = batch_axes_spec()
    if ba is None:
        return out
    if not sp_residual():
        return out
    return constrain(out, P(None, ba, "model", None, None))


# weight use-time specs by parameter name (mirrors runtime/sharding.py
# storage rules with the 'data' storage axis stripped)
_USE_SPECS = {
    "wq": P(None, "model"), "wk": P(None, "model"), "wv": P(None, "model"),
    "w_qkv": P(None, "model"), "w_o": P(None, "model"),
    "w_if": P(None, "model"),
    "in_proj": P(None, "model"), "w": P(None, "model"),
    "w_z": P(None, "model"), "w_x": P(None, "model"),
    "w_B": P(None, "model"), "w_C": P(None, "model"),
    "w_dt": P(None, "model"),
    "wo": P("model", None), "out_proj": P("model", None),
    "lm_head": P(None, "model"),
}
_USE_SPECS_FFN = {
    "w_gate": P(None, "model"), "w_up": P(None, "model"),
    "w_down": P("model", None),
}
# MoE experts are used with their storage sharding — never gathered (a
# 1T-param expert gather would be absurd) and never re-constrained (the
# storage spec is mode-dependent; see runtime/sharding.py)
_USE_SPECS_MOE = {}


def unshard_block_params(p: dict) -> dict:
    """Apply use-time (TP-only) constraints to a block's parameter dict.

    Leaves not named here (norms, biases, metadata) pass through. The
    constraint is a no-op when hints are disabled or no mesh is ambient.
    """
    if not enabled() or _axes() is None:
        return p

    def walk(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                if k == "moe":
                    sub = dict(v)
                    for kk, spec in _USE_SPECS_MOE.items():
                        if kk in sub and sub[kk].ndim == 3:
                            sub[kk] = constrain(sub[kk], spec)
                    if "shared" in sub:
                        sh = dict(sub["shared"])
                        for kk, spec in _USE_SPECS_FFN.items():
                            if kk in sh:
                                sh[kk] = constrain(sh[kk], spec)
                        sub["shared"] = sh
                    out[k] = sub
                elif k == "ffn":
                    sub = dict(v)
                    for kk, spec in _USE_SPECS_FFN.items():
                        if kk in sub:
                            sub[kk] = constrain(sub[kk], spec)
                    out[k] = sub
                else:
                    out[k] = walk(v)
            else:
                spec = _USE_SPECS.get(k)
                if spec is not None and v.ndim == len(spec):
                    out[k] = constrain(v, spec)
                else:
                    out[k] = v
        return out

    return walk(p)
