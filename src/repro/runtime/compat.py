"""Version compatibility shims for the pinned jax (0.4.37).

Two jax 0.5+ APIs leak into this codebase's sharding plumbing and tests:

  * ``jax.sharding.get_abstract_mesh`` — explicit-sharding mode's ambient
    abstract mesh. Under 0.4.x there is no abstract mesh; the only ambient
    mesh is the physical one in thread resources, so the correct degraded
    behavior is "no abstract mesh" (return None) and let callers fall back
    to the physical-mesh lookup.
  * ``jax.sharding.AxisType`` + the ``axis_types=`` kwarg of
    ``jax.make_mesh`` — axis kinds (Auto/Explicit) for the explicit-
    sharding rollout. 0.4.x meshes are implicitly all-Auto, which is
    exactly what every call site here wants, so the degraded behavior is
    to omit the kwarg.

Keep ALL version probing in this module: call sites use
``get_abstract_mesh()`` / ``make_mesh()`` unconditionally.
"""
from __future__ import annotations

import jax

#: jax.sharding.AxisType when available (jax >= 0.5), else None.
AXIS_TYPE = getattr(jax.sharding, "AxisType", None)

_GET_ABSTRACT_MESH = getattr(jax.sharding, "get_abstract_mesh", None)


def get_abstract_mesh():
    """The ambient abstract mesh, or None when unsupported / unset.

    jax >= 0.5 returns an (possibly empty) AbstractMesh; callers should
    treat both None and ``mesh.empty`` as "no abstract mesh".
    """
    if _GET_ABSTRACT_MESH is None:
        return None
    try:
        return _GET_ABSTRACT_MESH()
    except Exception:
        return None


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across the 0.4 → 0.5 API move.

    jax >= 0.5 exposes top-level ``jax.shard_map`` with ``check_vma``;
    0.4.x has ``jax.experimental.shard_map.shard_map`` with ``check_rep``.
    ``check`` maps onto whichever knob exists.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    from jax.experimental.shard_map import shard_map as sm_exp

    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


def make_mesh(shape, axis_names, *, auto_axes: bool = True):
    """``jax.make_mesh`` that requests Auto axis types where supported.

    Under jax 0.4.x (no AxisType) the kwarg is omitted — 0.4.x meshes are
    implicitly auto-sharded, so behavior is identical.
    """
    if AXIS_TYPE is not None and auto_axes:
        try:
            return jax.make_mesh(
                shape, axis_names,
                axis_types=(AXIS_TYPE.Auto,) * len(axis_names))
        except TypeError:
            pass  # make_mesh predates axis_types despite AxisType existing
    return jax.make_mesh(shape, axis_names)
