"""Serving step builders: prefill + decode with H²EAL layouts.

The decode step comes in two compiled variants (select / reuse) realizing
the paper's shared page selection: the serving loop calls the `select`
variant every ``share_window`` steps and the cheaper `reuse` variant in
between — no lax.cond, so each variant's HLO (and roofline) is exact.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.runtime import sharding as shardlib


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    capacity: int                 # max context tokens the cache holds
    layout: str = "default"       # core/layouts registry name; the
                                  # legacy None/"auto" spellings resolve
                                  # with a one-shot DeprecationWarning
                                  # (state_shardings keeps its batch-size
                                  # auto rule for an explicit None)
    impl: str = "ref"             # attention kernels: "ref" | "pallas"
                                  # (kernels/ops.py; baked into the
                                  # compiled steps, never a runtime switch)


def _layout(scfg: ServeConfig) -> str:
    """Canonical layout name for the model step functions; raises on
    unknown names with the registered list (core/layouts.py)."""
    from repro.core import layouts as layoutlib

    return layoutlib.resolve_layout(scfg.layout)


def make_prefill(cfg: ArchConfig, scfg: ServeConfig):
    layout = _layout(scfg)

    def prefill(params, batch):
        return M.prefill(cfg, params, batch, capacity=scfg.capacity,
                         impl=scfg.impl, layout=layout)
    return prefill


def make_decode_step(cfg: ArchConfig, scfg: ServeConfig, *, do_select: bool):
    layout = _layout(scfg)

    def decode(params, state, token):
        return M.decode_step(cfg, params, state, token,
                             do_select=do_select, impl=scfg.impl,
                             layout=layout)
    return decode


def make_ragged_decode_step(cfg: ArchConfig, scfg: ServeConfig, *,
                            do_select: bool):
    """Decode step for the continuous-batching engine (repro.serving).

    ``state["length"]`` is per-slot (B,); ``active`` masks live slots. The
    select variant additionally takes ``need_select`` — the per-slot
    share-window phase mask — so each slot refreshes its page selection on
    its own cadence while sharing one compiled program.
    """
    layout = _layout(scfg)
    if do_select:
        def decode(params, state, token, active, need_select):
            return M.decode_step(cfg, params, state, token, do_select=True,
                                 impl=scfg.impl, layout=layout,
                                 active=active, need_select=need_select)
    else:
        def decode(params, state, token, active):
            return M.decode_step(cfg, params, state, token, do_select=False,
                                 impl=scfg.impl, layout=layout,
                                 active=active)
    return decode


def make_prefill_chunk_step(cfg: ArchConfig, scfg: ServeConfig, *,
                            chunk: int):
    """Chunked-prefill half of the engine's mixed prefill+decode step.

    Feeds each prefilling slot's next prompt chunk (≤ ``chunk`` tokens,
    STATIC shape — the chunk-size bucket) directly into the slot's rows
    of the batched sharded serve state through the layout protocol
    (core/layouts.py ``prefill_chunk``). Per-slot chunk lengths and the
    prefilling mask are dynamic, so one compiled program serves every
    chunk schedule.
    """
    layout = _layout(scfg)

    def chunk_step(params, state, tokens, chunk_len, active):
        assert tokens.shape[1] == chunk, (tokens.shape, chunk)
        return M.prefill_chunk(cfg, params, state, tokens,
                               chunk_len=chunk_len, active=active,
                               impl=scfg.impl, layout=layout)
    return chunk_step


def jit_serve_steps(cfg: ArchConfig, scfg: ServeConfig, mesh: Mesh, params,
                    state, batch_size: int):
    """Returns (prefill_fn, decode_select_fn, decode_reuse_fn) jitted with
    explicit shardings."""
    ps = shardlib.param_shardings(cfg, mesh, params, mode="serve")
    ss = shardlib.state_shardings(cfg, mesh, state, layout=scfg.layout,
                                  batch_size=batch_size)
    bs = shardlib.batch_sharding(mesh, batch_size)
    scalar = NamedSharding(mesh, P())

    prefill = jax.jit(
        make_prefill(cfg, scfg),
        in_shardings=(ps, bs),
        out_shardings=(bs, ss),
    )
    dec_sel = jax.jit(
        make_decode_step(cfg, scfg, do_select=True),
        in_shardings=(ps, ss, bs),
        out_shardings=(bs, ss),
        donate_argnums=(1,),
    )
    dec_reuse = jax.jit(
        make_decode_step(cfg, scfg, do_select=False),
        in_shardings=(ps, ss, bs),
        out_shardings=(bs, ss),
        donate_argnums=(1,),
    )
    return prefill, dec_sel, dec_reuse
