"""Serving step builders: prefill + decode with H²EAL layouts.

The decode step comes in two compiled variants (select / reuse) realizing
the paper's shared page selection: the serving loop calls the `select`
variant every ``share_window`` steps and the cheaper `reuse` variant in
between — no lax.cond, so each variant's HLO (and roofline) is exact.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.runtime import sharding as shardlib


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    capacity: int                 # max context tokens the cache holds
    layout: str = "default"       # core/layouts registry name; the
                                  # legacy None/"auto" spellings resolve
                                  # with a one-shot DeprecationWarning
                                  # (state_shardings keeps its batch-size
                                  # auto rule for an explicit None)
    impl: str = "ref"             # attention kernels: "ref" | "pallas"
                                  # (kernels/ops.py; baked into the
                                  # compiled steps, never a runtime switch)


def _layout(scfg: ServeConfig) -> str:
    """Canonical layout name for the model step functions; raises on
    unknown names with the registered list (core/layouts.py)."""
    from repro.core import layouts as layoutlib

    return layoutlib.resolve_layout(scfg.layout)


def make_prefill(cfg: ArchConfig, scfg: ServeConfig):
    layout = _layout(scfg)

    def prefill(params, batch):
        return M.prefill(cfg, params, batch, capacity=scfg.capacity,
                         impl=scfg.impl, layout=layout)
    return prefill


def make_decode_step(cfg: ArchConfig, scfg: ServeConfig, *, do_select: bool):
    layout = _layout(scfg)

    def decode(params, state, token):
        return M.decode_step(cfg, params, state, token,
                             do_select=do_select, impl=scfg.impl,
                             layout=layout)
    return decode


def make_ragged_decode_step(cfg: ArchConfig, scfg: ServeConfig, *,
                            do_select: bool):
    """Decode step for the continuous-batching engine (repro.serving).

    ``state["length"]`` is per-slot (B,); ``active`` masks live slots. The
    select variant additionally takes ``need_select`` — the per-slot
    share-window phase mask — so each slot refreshes its page selection on
    its own cadence while sharing one compiled program.
    """
    layout = _layout(scfg)
    if do_select:
        def decode(params, state, token, active, need_select):
            return M.decode_step(cfg, params, state, token, do_select=True,
                                 impl=scfg.impl, layout=layout,
                                 active=active, need_select=need_select)
    else:
        def decode(params, state, token, active):
            return M.decode_step(cfg, params, state, token, do_select=False,
                                 impl=scfg.impl, layout=layout,
                                 active=active)
    return decode


def make_sample_step(cfg: ArchConfig, scfg: ServeConfig):
    """Batched per-slot sampler for the engine's decode loop (PR 8).

    (logits (B, V), base (B, 2) uint32, gen (B,) int32, temp/topp (B,),
    active (B,)) -> (tokens (B,) int32, gen') — greedy is the temp==0
    lane of the same compiled program, per-token keys derive in-graph
    from the request-owned base keys, and gen advances for active slots
    only, so one program serves every step (the zero-recompile
    invariant; serving/sampling.py has the RNG-ownership story).
    """
    del cfg, scfg  # sampling is model- and layout-independent

    def sample(logits, base, gen, temp, topp, active):
        from repro.serving import sampling

        tok = sampling.sample_tokens(logits, base, gen, temp, topp)
        return tok, jnp.where(active, gen + 1, gen)
    return sample


def make_verify_step(cfg: ArchConfig, scfg: ServeConfig, *, k: int):
    """Speculative verify step at the static (B, k) bucket (PR 8).

    tokens (B, k) int32: row 0 each slot's pending feed token, rows
    1..k-1 the draft. One compiled program per k: verify-forward over the
    pre-append caches (models/model.verify_forward), coupled target
    sampling (each chunk position uses EXACTLY the per-request key the
    non-speculative sampler would — serving/sampling.sample_chunk), the
    rejection-sampling acceptance rule, and the accepted-prefix commit.

    For point-mass drafts the coupled rule (accept draft d_j iff it
    equals the target sampled from p_j with that position's key; on the
    first mismatch emit the target) IS leftover-probability rejection
    sampling — P(accept) = p_j(d_j), and the emitted token on reject is
    distributed as norm((p_j - q_j)+) — so the output trace is not just
    distributionally but samplewise identical to non-speculative
    sampling, and greedy (temp=0) degenerates to "accept while the draft
    matches argmax". ``max_emit`` (B,) is the engine's host-side clamp
    (share-window boundary, budget, capacity) — acceptance never crosses
    a selection-refresh boundary mid-chunk. Returns
    (targets (B, k), accepted (B,), next_tok (B,), gen', state').
    """
    from repro.serving import sampling

    layout = _layout(scfg)

    def verify(params, state, tokens, active, need_select, base, gen,
               temp, topp, max_emit):
        assert tokens.shape[1] == k, (tokens.shape, k)
        logits, state1, stash = M.verify_forward(
            cfg, params, state, tokens, active=active,
            need_select=need_select, impl=scfg.impl, layout=layout)
        targets = sampling.sample_chunk(logits, base, gen, temp, topp)
        matches = tokens[:, 1:] == targets[:, :-1]          # (B, k-1)
        n_nat = 1 + jnp.sum(
            jnp.cumprod(matches.astype(jnp.int32), axis=1), axis=1)
        n = jnp.clip(n_nat, 1, jnp.maximum(max_emit, 1)).astype(jnp.int32)
        state2 = M.verify_commit(cfg, state1, stash, accepted=n,
                                 active=active, impl=scfg.impl,
                                 layout=layout)
        next_tok = jnp.take_along_axis(targets, (n - 1)[:, None],
                                       axis=1)[:, 0]
        new_gen = jnp.where(active, gen + n, gen)
        return targets, n, next_tok, new_gen, state2
    return verify


def make_prefill_chunk_step(cfg: ArchConfig, scfg: ServeConfig, *,
                            chunk: int):
    """Chunked-prefill half of the engine's mixed prefill+decode step.

    Feeds each prefilling slot's next prompt chunk (≤ ``chunk`` tokens,
    STATIC shape — the chunk-size bucket) directly into the slot's rows
    of the batched sharded serve state through the layout protocol
    (core/layouts.py ``prefill_chunk``). Per-slot chunk lengths and the
    prefilling mask are dynamic, so one compiled program serves every
    chunk schedule.
    """
    layout = _layout(scfg)

    def chunk_step(params, state, tokens, chunk_len, active):
        assert tokens.shape[1] == chunk, (tokens.shape, chunk)
        return M.prefill_chunk(cfg, params, state, tokens,
                               chunk_len=chunk_len, active=active,
                               impl=scfg.impl, layout=layout)
    return chunk_step


def make_fused_window_step(cfg: ArchConfig, scfg: ServeConfig, *,
                           window: int, chunk: int | None = None):
    """Fused decode window: ``window`` reuse steps as ONE program (PR 10).

    A ``lax.scan`` over the reuse-step body with sampling folded in-scan
    and device-side retirement: slot i emits exactly ``budgets[i]``
    tokens (sched/windows.window_budgets — the host-encoded stop
    conditions), then its lane of the carried ``active`` mask flips and
    the remaining iterations leave its rows untouched, bit-identically
    to the per-step loop going inactive. The scan realization routes
    through the layout registry (core/layouts.py ``decode_window``), so
    every entry — including the shard_map ``coplace_shmap`` body —
    inherits fusion without layout-specific engine code.

    Decode-only variant (``chunk=None``)::

        fused(params, state, tok, active, gen, budgets, base, temp, topp)
          -> (trace (window, B) int32, state', tok', gen')

    Mixed variant (``chunk=C``) additionally threads the engine's
    host-presimulated chunked-prefill schedule through the scan — per
    iteration a (B, C) token block + per-slot chunk lengths, applied
    BEFORE the decode half exactly like the per-step mixed step, plus a
    ``finish`` mask marking rows whose prompt completes that iteration
    (their first token is sampled from the chunk logits with gen=0, the
    same program lane as ``Engine._first_token``)::

        fused(params, state, tok, active, gen, budgets, base, temp, topp,
              chunk_tokens (window, B, C), chunk_lens (window, B),
              finish (window, B)) -> (trace, state', tok', gen')

    Rows of ``trace`` beyond a slot's budget hold its last token (the
    where-carry), never fresh samples; the engine slices per-slot
    prefixes on the host. Iterations past the useful length are full
    no-ops (all-inactive masks), so one compiled entry serves every
    boundary residue — the zero-recompile invariant.
    """
    from repro.core import layouts as layoutlib
    from repro.serving import sampling

    layout = _layout(scfg)

    def _decode_half(params, state, tok, act, gen, emitted, budgets,
                     base, temp, topp):
        logits, state = M.decode_step(cfg, params, state, tok,
                                      do_select=False, impl=scfg.impl,
                                      layout=layout, active=act)
        t = sampling.sample_tokens(logits, base, gen, temp, topp)
        tok = jnp.where(act, t, tok)
        gen = jnp.where(act, gen + 1, gen)
        emitted = emitted + act.astype(jnp.int32)
        act = act & (emitted < budgets)
        return state, tok, act, gen, emitted

    if chunk is None:
        def fused(params, state, tok, active, gen, budgets, base, temp,
                  topp):
            def body(carry, _):
                state, tok, act, gen, emitted = carry
                state, tok, act, gen, emitted = _decode_half(
                    params, state, tok, act, gen, emitted, budgets,
                    base, temp, topp)
                return (state, tok, act, gen, emitted), tok

            carry0 = (state, tok, active, gen, jnp.zeros_like(budgets))
            carry, trace = layoutlib.dispatch_decode_window(
                layout, body, carry0, None, length=window)
            state, tok, _, gen, _ = carry
            return trace, state, tok, gen
    else:
        def fused(params, state, tok, active, gen, budgets, base, temp,
                  topp, chunk_tokens, chunk_lens, finish):
            assert chunk_tokens.shape[0] == window, chunk_tokens.shape
            assert chunk_tokens.shape[2] == chunk, chunk_tokens.shape

            def body(carry, xs):
                state, tok, act, gen, emitted = carry
                ctoks, clens, fin = xs
                logits_c, state = M.prefill_chunk(
                    cfg, params, state, ctoks, chunk_len=clens,
                    active=clens > 0, impl=scfg.impl, layout=layout)
                first = sampling.sample_tokens(
                    logits_c, base, jnp.zeros_like(gen), temp, topp)
                tok = jnp.where(fin, first, tok)
                gen = jnp.where(fin, jnp.ones_like(gen), gen)
                state, tok, act, gen, emitted = _decode_half(
                    params, state, tok, act, gen, emitted, budgets,
                    base, temp, topp)
                return (state, tok, act, gen, emitted), tok

            carry0 = (state, tok, active, gen, jnp.zeros_like(budgets))
            carry, trace = layoutlib.dispatch_decode_window(
                layout, body, carry0, (chunk_tokens, chunk_lens, finish),
                length=window)
            state, tok, _, gen, _ = carry
            return trace, state, tok, gen
    return fused


def jit_serve_steps(cfg: ArchConfig, scfg: ServeConfig, mesh: Mesh, params,
                    state, batch_size: int):
    """Returns (prefill_fn, decode_select_fn, decode_reuse_fn) jitted with
    explicit shardings."""
    ps = shardlib.param_shardings(cfg, mesh, params, mode="serve")
    ss = shardlib.state_shardings(cfg, mesh, state, layout=scfg.layout,
                                  batch_size=batch_size)
    bs = shardlib.batch_sharding(mesh, batch_size)
    scalar = NamedSharding(mesh, P())

    prefill = jax.jit(
        make_prefill(cfg, scfg),
        in_shardings=(ps, bs),
        out_shardings=(bs, ss),
    )
    dec_sel = jax.jit(
        make_decode_step(cfg, scfg, do_select=True),
        in_shardings=(ps, ss, bs),
        out_shardings=(bs, ss),
        donate_argnums=(1,),
    )
    dec_reuse = jax.jit(
        make_decode_step(cfg, scfg, do_select=False),
        in_shardings=(ps, ss, bs),
        out_shardings=(bs, ss),
        donate_argnums=(1,),
    )
    return prefill, dec_sel, dec_reuse
