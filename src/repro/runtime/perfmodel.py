"""Analytical per-device byte model for the roofline memory term.

Why analytical: XLA:CPU's ``cost_analysis()`` charges (a) gathers at FULL
operand size (measured: 135 MB charged for a 1 MB page gather — the exact
sparse-access benefit H²EAL exists to exploit), (b) scan xs/ys slice
fusions at the full stacked buffer per iteration, and (c) while bodies
without trip multiplication. Those artifacts are 10–100× the real traffic
for paged decode, so the memory term here is computed from first
principles — the same accounting the paper's cycle-level simulator does —
from the known step semantics, sharded shapes and dtypes. The raw HLO
"bytes accessed" is reported alongside as a diagnostic.

All results are bytes PER DEVICE PER STEP for the production bf16 wire
format (metadata f32 where the implementation keeps f32).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig

BF16 = 2
F32 = 4


@dataclass(frozen=True)
class MeshModel:
    chips: int
    data: int          # data-axis size (x pod)
    model: int         # model-axis size


def _dp_shard(n: int, ways: int) -> float:
    """Per-device share of dim n sharded `ways`-way (1 if not divisible)."""
    return n / ways if n % ways == 0 else n


def _head_shard(h: int, ways: int) -> float:
    return h / ways if h % ways == 0 else h


def decode_bytes(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshModel,
                 *, layout: str, do_select: bool = True) -> dict:
    """One decode step (serve_step), per device."""
    h2 = cfg.h2eal
    b = shape.global_batch
    s = shape.seq_len
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hkv = cfg.num_kv_heads
    n_attn = len(cfg.attention_layers)
    n_layers = cfg.num_layers

    # weights: the whole (active) model is read once per decode step
    w_bytes = cfg.active_param_count() * BF16 / mesh.chips

    b_dev = _dp_shard(b, mesh.data)
    terms = {"weights": w_bytes}

    if not cfg.has_attention:
        # SSM/xLSTM: recurrent state read+write
        state = b_dev * cfg.num_layers * d * 64 * F32 * 2  # approx state dim
        terms["state"] = state
        terms["total"] = w_bytes + state
        return terms

    if not h2.enabled:
        # full-attention baseline: read the whole KV cache every step
        kv = (b_dev * _head_shard(hkv, mesh.model) * s * hd * BF16 * 2
              * n_attn)
        terms["kv_full"] = kv
        terms["total"] = w_bytes + kv
        return terms

    nr = hkv - round(hkv * h2.static_sparsity)
    ns = hkv - nr
    p = h2.page_size
    n_sink = -(-h2.sink // p)
    n_local = -(-h2.local // p) + 1
    n_pages_att = n_sink + h2.top_k_pages + n_local
    c_pages = -(-s // p)

    if layout == "head":
        hr_dev = _head_shard(nr, mesh.model)
        page_frac = 1.0
        b_kv = b_dev
    else:
        # coplace/interleave: pages (and within-page tokens) sharded — each
        # device holds 1/model (x 1/data for interleave) of every head's
        # pages and computes partial attention for what it stores
        hr_dev = nr
        ways = mesh.model * (mesh.data if layout == "interleave" else 1)
        page_frac = 1.0 / min(ways, n_pages_att * p)  # can't shard below 1 tok
        # batch stays data-sharded except pure interleave (B < data)
        b_kv = b if layout == "interleave" else b_dev

    # retrieval: gathered pages (k+v) per attention layer
    kv_sel = (b_kv * hr_dev * n_pages_att * p * hd * BF16 * 2 * page_frac
              * n_attn)
    # metadata scan (tau_min+tau_max, f32) — only on selection steps
    meta = (b_kv * hr_dev * c_pages * hd * F32 * 2 * page_frac * n_attn
            if do_select else 0.0)
    # streaming heads: sink+local ring (k+v)
    hs_dev = _head_shard(ns, mesh.model)
    kv_stream = (b_dev * hs_dev * (h2.sink + h2.local + p) * hd * BF16 * 2
                 * n_attn)
    # cache append writes (1 token/head) — negligible but counted
    appends = b_dev * hkv * hd * BF16 * 2 * n_attn

    terms.update({"kv_selected": kv_sel, "metadata": meta,
                  "kv_stream": kv_stream, "appends": appends})
    terms["total"] = sum(terms.values())
    return terms


def tier_page_bytes(cfg: ArchConfig) -> float:
    """Wire bytes of ONE logical KV page crossing the hot/cold residency
    boundary (core/cache.TieredPagedCache spill or fill): K + V rows of
    every retrieval head in every attention layer. Streaming heads keep
    a ring, not pages, and page metadata (tau/importance/page_start)
    never migrates — selection must stay metadata-complete on the hot
    side for cold misses to be detectable."""
    h2 = cfg.h2eal
    hkv = cfg.num_kv_heads
    nr = hkv - round(hkv * h2.static_sparsity) if h2.enabled else hkv
    n_attn = len(cfg.attention_layers) or cfg.num_layers
    return float(2 * h2.page_size * cfg.resolved_head_dim * BF16
                 * nr * n_attn)


def tier_traffic_bytes(cfg: ArchConfig, *, fills: int, spills: int,
                       prefetch: int) -> dict:
    """Far-bank traffic of a tiered-residency serving run, from the
    engine's page counters (EngineStats.tier_fills/spills/prefetch).

    ``blocking`` isolates the demand fills: a cold SELECTED page stalls
    its select step until the fill lands, while prefetch and spill
    traffic overlaps decode (scheduled one share window ahead of the
    refresh that needs it). The hbsim far-bank link model
    (hbsim.sim.far_bank_transfer) converts these bytes to time/energy.
    """
    page = tier_page_bytes(cfg)
    terms = {
        "demand_fills": fills * page,
        "prefetch": prefetch * page,
        "spills": spills * page,
    }
    terms["blocking"] = terms["demand_fills"]
    terms["total"] = (terms["demand_fills"] + terms["prefetch"]
                      + terms["spills"])
    return terms


def migration_slot_bytes(cfg: ArchConfig, *, ctx: int) -> float:
    """Wire bytes of moving ONE slot's cache row between slot indices
    (serving.Engine._migrate_slot, planned by sched/rebalance.py):
    K + V of the slot's live retrieval-head pages, the streaming-head
    sink+local ring, and the per-page f32 selection metadata (tau
    min/max d-vectors), summed over attention layers. The migrated
    bytes cross banks, so the hbsim NoC-link model prices them
    (hbsim.sim.rebalance_overhead) against the imbalance they remove."""
    h2 = cfg.h2eal
    hkv = cfg.num_kv_heads
    nr = hkv - round(hkv * h2.static_sparsity) if h2.enabled else hkv
    ns = hkv - nr
    hd = cfg.resolved_head_dim
    n_attn = len(cfg.attention_layers) or cfg.num_layers
    pages = -(-int(ctx) // h2.page_size) if ctx > 0 else 0
    paged_kv = 2 * pages * h2.page_size * hd * BF16 * nr
    ring_kv = 2 * min(int(ctx), h2.sink + h2.local) * hd * BF16 * ns
    meta = 2 * pages * hd * F32 * nr
    return float((paged_kv + ring_kv + meta) * n_attn)


def migration_traffic_bytes(cfg: ArchConfig, *, migrations: int,
                            migrated_tokens: int) -> float:
    """Total migration traffic of a serving run from the engine's
    counters (EngineStats.migrations / migrated_tokens): each move is
    priced at the mean migrated context length. All of it overlaps
    decode (migration runs between steps, never inside one), so it
    costs link occupancy and energy, not critical-path stalls."""
    if migrations <= 0:
        return 0.0
    mean_ctx = migrated_tokens / migrations
    return migrations * migration_slot_bytes(cfg, ctx=int(round(mean_ctx)))


def prefill_bytes(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshModel,
                  *, q_chunk: int = 1024) -> dict:
    """Prefill step, per device: activations dominate; chunked attention
    re-reads K/V once per q-chunk (full layers) or the window span (local
    layers)."""
    h2 = cfg.h2eal
    b = shape.global_batch
    s = shape.seq_len
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hkv = cfg.num_kv_heads
    n_attn = len(cfg.attention_layers)

    w_bytes = cfg.active_param_count() * BF16 / mesh.chips
    b_dev = _dp_shard(b, mesh.data)
    tokens_dev = b_dev * s
    # per layer: read x (qkv+ffn ins) + write outs ≈ 8 d-vectors per token
    act = tokens_dev * d * BF16 * 8 * cfg.num_layers
    # attention K/V re-reads: full-causal layers read K,V per q-chunk
    nr = hkv - round(hkv * h2.static_sparsity) if h2.enabled else hkv
    ns = hkv - nr
    n_chunks = max(1, s // q_chunk)
    kv_full = (b_dev * _head_shard(nr, mesh.model) * s * hd * BF16 * 2
               * n_chunks * n_attn)
    # streaming-head layers only read the window span per chunk
    kv_win = (b_dev * _head_shard(ns, mesh.model)
              * (q_chunk + h2.local + h2.sink) * hd * BF16 * 2
              * n_chunks * n_attn)
    # cache build writes
    cache_w = (b_dev * hkv * s * hd * BF16 * 2 * n_attn
               / (mesh.model if hkv % mesh.model == 0 else 1))

    terms = {"weights": w_bytes, "activations": act, "kv_full": kv_full,
             "kv_window": kv_win, "cache_write": cache_w}
    terms["total"] = sum(terms.values())
    return terms


def train_bytes(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshModel,
                *, microbatches: int = 1, q_chunk: int = 1024) -> dict:
    """Training step per device: fwd + bwd (≈2x fwd traffic) + remat
    re-forward + optimizer state (f32 m,v read+write, f32 params
    read+write, grads f32 write+read)."""
    fwd = prefill_bytes(cfg, shape, mesh, q_chunk=q_chunk)
    p_dev = cfg.param_count() / mesh.chips
    opt = p_dev * F32 * (2 + 2 + 2 + 2)  # p rw, m rw, v rw, g rw
    # fwd + remat-fwd + bwd(≈2x fwd)
    compute_traffic = fwd["total"] * 4
    terms = {"fwd_bwd_remat": compute_traffic, "optimizer": opt}
    terms["total"] = compute_traffic + opt
    return terms


def cell_bytes(cfg: ArchConfig, shape: ShapeConfig, mesh: MeshModel,
               *, layout: str = "head", microbatches: int = 1) -> dict:
    if shape.kind == "train":
        return train_bytes(cfg, shape, mesh, microbatches=microbatches)
    if shape.kind == "prefill":
        return prefill_bytes(cfg, shape, mesh)
    return decode_bytes(cfg, shape, mesh, layout=layout)
