"""Sharding rules: how H²EAL's bank placement maps onto the TPU mesh.

Mesh axes: ``("data","model")`` single pod (16x16), ``("pod","data","model")``
multi-pod. ``pod`` composes with ``data`` for batch sharding (DP across
pods — DCN-crossing collectives stay in the gradient/batch reduction).

Parameters are 2D-sharded (TP over ``model`` on the contraction-output
dim, FSDP/ZeRO over ``data`` on the other dim) so even kimi-k2 (1T params)
fits per-device HBM. Experts shard E over ``model`` plus an inner dim over
``data`` (EP x TP).

Serve-cache layouts (the paper's §IV-B mapped to mesh axes):

  head       — baseline "head parallelism": kv-heads → model, batch → data.
               (the paper's basic HB implementation, Fig 3a)
  coplace    — memory-compute co-placement: pages (C dim) → model, so each
               device owns whole pages and computes partial attention for
               the pages it stores; batch → data.
  interleave — co-placement + interleaved storage: pages → model AND the
               within-page token dim (P) → data: every page is striped
               across the data axis, so any top-k selection lands uniformly
               on all devices (paper Fig 7b). Default for long_500k where
               batch cannot feed the mesh.

The layouts themselves are registry entries (core/layouts.py,
AttentionLayout): each entry owns its paged-cache leaf placement via
``cache_axes``; this module turns those axis tuples into PartitionSpecs
and handles everything layout-independent.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


def _axis(mesh: Mesh, name: str):
    return name if name in mesh.axis_names else None


def batch_axes(mesh: Mesh):
    """Axes for the global-batch dim: ('pod','data') when pod exists."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def _div(n: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    size = int(np.prod([mesh.shape[a] for a in
                        (axes if isinstance(axes, tuple) else (axes,))]))
    return n % size == 0


# params whose (p, m, v) f32 optimizer footprint fits TP-only per device
# skip FSDP entirely — ZeRO-3 weight re-gathers per microbatch dominate
# small-model training collectives otherwise (measured on zamba2/smollm)
FSDP_BYTES_THRESHOLD = 8e9


def _spec_for_param(path: str, shape, mesh: Mesh, stacked: bool,
                    mode: str = "train", fsdp_on: bool = True):
    """PartitionSpec for a parameter leaf.

    train/opt: ZeRO-3 — weights stored 2D (FSDP 'data' × TP 'model'); the
           use-time TP-only constraint (runtime/hints.py) turns the
           storage→use transfer into a weight all-gather. (A ZeRO-1
           variant — TP-only bf16 params, FSDP'd optimizer — was measured
           and is NOT better at these scales; see EXPERIMENTS.md §Perf.)
    serve: TP-only over 'model' (no optimizer state; gathering weights
           every decode step would dwarf the sparse-attention win). MoE
           experts stay 2D (E → 'data' EP, d → 'model' TP) at serve —
           a 1T-param MoE cannot live TP-16.
    """
    nd = len(shape)
    inner = shape[1:] if stacked else shape
    fsdp = "data" if (mode in ("train", "opt") and fsdp_on) else None

    def build(*axes):
        axes = list(axes) + [None] * (len(inner) - len(axes))
        # drop axes that don't divide (GSPMD tolerates uneven sharding but
        # aligned shards keep layouts clean; fall back to replication)
        axes = [a if _div(inner[i], mesh, a) else None
                for i, a in enumerate(axes)]
        if stacked:
            axes = [None] + axes
        return P(*axes)

    if "embed" in path:
        return build("model", None)
    if "lm_head" in path:
        return build(fsdp, "model")
    # MoE experts. train: E -> model (EP) x d/f -> data (FSDP slice;
    # measured best of three candidates — E->data x d->model and
    # unsharded-inner both regressed 7-11x, see EXPERIMENTS.md §Perf).
    # serve: E -> data, d -> model (decode batches are tiny; EP across
    # data keeps 1T-param experts resident).
    if "w_gate" in path or "w_up" in path:
        if len(inner) == 3:
            return (build("model", "data", None) if mode in ("train", "opt")
                    else build("data", "model", None))
        return build(fsdp, "model")
    if "w_down" in path:
        if len(inner) == 3:
            return (build("model", None, "data") if mode in ("train", "opt")
                    else build("data", None, "model"))
        return build("model", fsdp)
    if "router" in path:
        return build(fsdp, None)
    if any(k in path for k in ("wq", "wk", "wv", "w_qkv", "w_o", "w_if",
                               "in_proj", "['w']", "w_z", "w_x", "w_B",
                               "w_C", "w_dt")):
        return build(fsdp, "model")
    if any(k in path for k in ("wo", "out_proj")):
        return build("model", fsdp)
    if "conv_w" in path or "['conv_x']" in path or "['conv_B']" in path \
            or "['conv_C']" in path:
        return build(None, "model")
    if "['r']" in path:  # slstm recurrent (h, p, 4p)
        return build("model", None, None)
    if any(k in path for k in ("bq", "bk", "bv", "b_if")):
        return build("model")
    return build(*([None] * len(inner)))


def param_shardings(cfg, mesh: Mesh, params, mode: str = "train"):
    """Pytree of NamedSharding matching ``params``."""
    fsdp_on = True
    if mode in ("train", "opt") and cfg is not None:
        opt_bytes = cfg.param_count() * 12 / mesh.shape["model"]
        fsdp_on = opt_bytes > FSDP_BYTES_THRESHOLD
    flat = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat[0]:
        pstr = jax.tree_util.keystr(path)
        stacked = "['blocks']" in pstr
        spec = _spec_for_param(pstr, leaf.shape, mesh, stacked, mode,
                               fsdp_on)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), out)


def replicated(mesh: Mesh):
    """Fully-replicated NamedSharding on ``mesh``."""
    return NamedSharding(mesh, P())


def serve_step_out_shardings(mesh: Mesh, state_shardings):
    """(logits, state) out_shardings pair for the serving engine's
    decode and prefill-chunk jits: per-step logits replicated, the
    batched serve state pinned to its layout placement — the sharded
    half of the zero-recompile invariant (docs/serving.md)."""
    return (replicated(mesh), state_shardings)


def verify_step_out_shardings(mesh: Mesh, state_shardings):
    """(targets, accepted, next_tok, gen', state) out_shardings for the
    speculative verify jit: the per-slot token/count vectors replicated,
    the serve state pinned to its layout placement."""
    rep = replicated(mesh)
    return (rep, rep, rep, rep, state_shardings)


def fused_window_out_shardings(mesh: Mesh, state_shardings):
    """(trace, state, tok, gen) out_shardings for the fused decode-window
    jit (runtime/serve.make_fused_window_step): the (window, B) token
    trace block and the per-slot token/gen vectors replicated, the serve
    state pinned to its layout placement so the scanned reuse body keeps
    the exact per-step placement — the fused half of the zero-recompile
    invariant (docs/serving.md §Fused decode windows)."""
    rep = replicated(mesh)
    return (rep, state_shardings, rep, rep)


def batch_sharding(mesh: Mesh, batch_size: int):
    """Sharding for (B, ...) input batches: B over (pod, data) if divisible."""
    ax = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in ax]))
    if batch_size % size == 0:
        return NamedSharding(mesh, P(ax))
    if "data" in mesh.axis_names and batch_size % mesh.shape["data"] == 0:
        return NamedSharding(mesh, P("data"))
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Serve-cache layouts
#
# The per-layout placement of the paged-cache leaves lives with the
# layout entries in core/layouts.py (AttentionLayout.cache_axes); this
# module keeps the generic machinery (batch axes, divisibility
# filtering, scan-stacked leaves) and the layout-independent leaves
# (stream ring, SSM/xLSTM state). The name constants are re-exported
# for backward compatibility.
# ---------------------------------------------------------------------------

from repro.core.layouts import (  # noqa: E402  (re-export)
    LAYOUT_COPLACE,
    LAYOUT_COPLACE_SHMAP,
    LAYOUT_HEAD,
    LAYOUT_INTERLEAVE,
)


def _cache_leaf_spec(path: str, shape, mesh: Mesh, layout_obj,
                     batch_ok: bool, stacked: bool):
    inner = shape[1:] if stacked else shape
    nd = len(inner)
    b_ax = batch_axes(mesh) if batch_ok else None

    def build(*axes):
        axes = (list(axes) + [None] * nd)[:nd]
        axes = [b_ax if a == "batch" else a for a in axes]
        axes = [a if _div(inner[i], mesh, a) else None
                for i, a in enumerate(axes)]
        if stacked:
            axes = [None] + axes
        return P(*axes)

    h_ax = "model"
    if "k_pages" in path or "v_pages" in path:      # (B, Hr, C, P, D)
        return build(*layout_obj.cache_axes("pages", batch_ok=batch_ok))
    if "tau_min" in path or "tau_max" in path:      # (B, Hr, C, D)
        return build(*layout_obj.cache_axes("tau", batch_ok=batch_ok))
    if "importance" in path or "page_start" in path:  # (B, Hr, C)
        return build(*layout_obj.cache_axes("meta", batch_ok=batch_ok))
    if "sel_idx" in path:                            # (B, Hr, K)
        return build(b_ax, None, None)
    # dataclass attributes render as ".k" in keystr (dicts as "['k']")
    if path.endswith(".k") or path.endswith(".v"):   # stream/full (B,H,T,D)
        return build(b_ax, h_ax, None, None)
    if "['ssm']" in path:                            # (B, H, N, P) state
        return build(b_ax, "model", None, None)
    if any(k in path for k in ("['conv']", "['conv_x']", "['conv_B']",
                               "['conv_C']")):                 # (B, K, C)
        return build(b_ax, None, "model")
    if "['C']" in path:                              # mlstm (B,H,P,P)
        return build(b_ax, "model", None, None)
    if path.endswith(".pos"):                        # stream ring (B, Hs, W)
        return build(b_ax, h_ax, None)
    if any(path.endswith(k) for k in ("['n']", "['m']", "['h']", "['c']")):
        return build(b_ax, "model")
    return build(*([None] * nd))


def state_shardings(cfg, mesh: Mesh, state, *, layout: str | None = None,
                    batch_size: int | None = None):
    """Pytree of NamedSharding for a ServeState.

    ``layout`` is resolved through the core/layouts registry (unknown
    names raise with the registered list). ``layout=None`` keeps the
    pre-registry auto rule: interleave when the batch can't fill
    (pod x data), head otherwise — i.e. H²EAL co-placement turns on
    exactly when plain data parallelism starves (the paper's
    motivation).
    """
    from repro.core import layouts as layoutlib

    ax = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in ax]))
    if layout is None:
        layout = (LAYOUT_INTERLEAVE
                  if (batch_size is not None and batch_size < dp)
                  else LAYOUT_HEAD)
    lay = layoutlib.get_layout(layout)
    batch_ok = batch_size is None or batch_size % dp == 0

    flat = jax.tree_util.tree_flatten_with_path(state)
    out = []
    for path, leaf in flat[0]:
        pstr = jax.tree_util.keystr(path)
        if "length" in pstr or not hasattr(leaf, "shape") or leaf.ndim == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        stacked = "['blocks']" in pstr
        spec = _cache_leaf_spec(pstr, leaf.shape, mesh, lay,
                                batch_ok, stacked)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state), out)
