from repro.hbsim.sim import (  # noqa: F401
    HBConfig,
    MODES,
    attention_decode,
    e2e_decode,
    gemm_decode,
)
