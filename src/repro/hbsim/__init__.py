from repro.hbsim.sim import (  # noqa: F401
    HBConfig,
    MODES,
    attention_decode,
    e2e_decode,
    far_bank_transfer,
    gemm_decode,
    rebalance_overhead,
    tiered_serving_overhead,
)
