"""Cycle/energy model of the H²EAL hybrid-bonding accelerator (Table II).

Hardware model (from the paper's Table II, [11][12][36]):
  * logic die: 16 banks in a 4x4 NoC; each bank a DCIM GEMM engine of
    16 macros x 900 GOPS @ int8 = 14.4 TOPS/bank; 24 TOPS/W.
  * memory: 4 stacked DRAM dies; per logic bank, each die contributes
    256 bits / 4 macros / cycle @ 400 MHz = 51.2 GB/s, so a bank sees
    4 x 51.2 = 204.8 GB/s and the chip 3.28 TB/s aggregate.
    Access energy 0.88 pJ/bit.
  * NoC: 256-bit 2-D mesh @ 400 MHz = 12.8 GB/s/link; hop energy assumed
    0.8 pJ/B (not in Table II; typical 22nm mesh — documented assumption).
  * quantization: W8A8KV8 (paper §V-A.2) — 1 byte/element everywhere.

Validation: with this model, full-attention LLaMA2-7B decode reproduces
Table III within ~10% (127.9 vs ~138 tok/s @64k, 40.8 vs ~43 @256k), and
H²EAL reproduces the 430-480 tok/s band and the ~70x attention energy
ratio of Fig 9 — see benchmarks/ and EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig, H2ealConfig
from repro.sched import balance as B
from repro.sched import mapping as MP
from repro.sched import tiling as TL


@dataclass(frozen=True)
class HBConfig:
    banks: int = 16
    grid: Tuple[int, int] = (4, 4)
    bank_tops: float = 14.4e12          # int8 ops/s per bank (16 x 900G)
    tops_per_watt: float = 24e12        # compute energy
    bank_mem_bw: float = 4 * 51.2e9     # 4 stacked dies per bank
    mem_energy_per_byte: float = 0.88e-12 * 8
    noc_link_bw: float = 12.8e9
    noc_energy_per_byte_hop: float = 0.8e-12
    sram_per_bank: int = 8 * 128 * 1024

    @property
    def chip_mem_bw(self) -> float:
        return self.banks * self.bank_mem_bw


MODES = ("full", "sparse_unbalanced", "h2eal")


@dataclass
class Cost:
    mem_bytes: float = 0.0
    ops: float = 0.0
    noc_bytes_hops: float = 0.0

    def __iadd__(self, o: "Cost"):
        self.mem_bytes += o.mem_bytes
        self.ops += o.ops
        self.noc_bytes_hops += o.noc_bytes_hops
        return self


def _head_decode_cost(kind: str, cfg: ArchConfig, h2: H2ealConfig,
                      seq: int, mode: str) -> Cost:
    """Per-KV-head, per-layer cost of one decode step (int8)."""
    d = cfg.resolved_head_dim
    g = cfg.num_heads // cfg.num_kv_heads
    if mode == "full" or not h2.enabled:
        tokens = seq
        meta_bytes = 0.0
    elif kind == "streaming":
        tokens = h2.sink + h2.local
        meta_bytes = 0.0
    else:  # retrieval head with page selection
        tokens = h2.sink + h2.local + h2.select_budget
        n_pages = seq / h2.page_size
        # tau_min + tau_max per page, amortized over the shared window
        meta_bytes = 2 * n_pages * d / max(h2.share_window, 1)
    kv_bytes = 2 * tokens * d            # K + V, int8
    # QK^T + PV for the whole GQA group (2 ops per MAC)
    ops = 2 * 2 * tokens * d * g
    if meta_bytes:
        ops += 2 * 2 * (seq / h2.page_size) * d / max(h2.share_window, 1)
    return Cost(mem_bytes=kv_bytes + meta_bytes, ops=ops)


def attention_decode(cfg: ArchConfig, seq: int, mode: str,
                     hb: HBConfig = HBConfig(),
                     h2: H2ealConfig | None = None) -> Dict:
    """One decode step of ALL attention layers. Returns latency (s),
    energy (J) and per-bank load breakdown for the balance ablation."""
    h2 = h2 or cfg.h2eal
    n_kv = cfg.num_kv_heads
    n_layers = len(cfg.attention_layers) or cfg.num_layers
    plan = MP.map_heads(n_kv, hb.banks)

    # head kinds: gating assigns types per head with no layout structure —
    # spread retrieval heads round-robin over the natural head order (the
    # arbitrary placement the load balancer must then fix; grouping them
    # here would accidentally balance the "unbalanced" baseline)
    n_s = round(n_kv * h2.static_sparsity) if mode != "full" else 0
    n_r = n_kv - n_s
    kinds = ["streaming"] * n_kv
    for i in range(n_r):
        kinds[(i * n_kv) // max(n_r, 1)] = "retrieval"

    total_latency = 0.0
    total_energy = 0.0
    bank_times_first_stage: List[float] = []

    for stage in plan.stages:
        # banks per head in this stage (tensor parallelism within group)
        bph = stage.banks_per_head
        head_costs = [_head_decode_cost(kinds[h], cfg, h2, seq, mode)
                      for h in stage.heads]
        # place heads on banks: one head -> bph banks
        if mode == "h2eal":
            # tile retrieval with streaming heads; within a tile the KV
            # work is split evenly (co-placement + interleaving)
            coords = TL.grid_coords(*hb.grid)[: len(stage.heads) * bph]
            head_of_bank = {}
            for i, hd in enumerate(stage.heads):
                for j in range(bph):
                    head_of_bank[coords[i * bph + j]] = hd
            retr = [c for c, hd in head_of_bank.items()
                    if kinds[hd] == "retrieval"]
            stre = [c for c, hd in head_of_bank.items()
                    if kinds[hd] == "streaming"]
            tiles, _ = TL.solve_tiling(retr, stre)
            bank_time = []
            for t in tiles:
                tot = Cost()
                for c in t.members:
                    hc = head_costs[stage.heads.index(head_of_bank[c])]
                    tot += Cost(hc.mem_bytes / bph, hc.ops / bph, 0)
                share_mem = tot.mem_bytes / len(t.members)
                share_ops = tot.ops / len(t.members)
                # cross-bank softmax combine: (m, l, o) ≈ (2 + head_dim)
                # values per head per member, over max_dist hops
                noc = (len(t.members) * (2 + cfg.resolved_head_dim)
                       * max(t.max_dist, 1))
                tme = max(share_mem / hb.bank_mem_bw,
                          share_ops / hb.bank_tops) + noc / hb.noc_link_bw
                bank_time.extend([tme] * len(t.members))
                total_energy += (tot.mem_bytes * len(t.members) / bph * 0
                                 + noc * hb.noc_energy_per_byte_hop)
            stage_latency = max(bank_time)
        else:
            # one head per bank-group; no sharing: slowest head gates all
            per_head_time = [
                max(hc.mem_bytes / bph / hb.bank_mem_bw,
                    hc.ops / bph / hb.bank_tops)
                for hc in head_costs]
            bank_time = [t for t in per_head_time for _ in range(bph)]
            stage_latency = max(per_head_time)
        bank_times_first_stage = bank_times_first_stage or bank_time
        total_latency += stage_latency
        for hc in head_costs:
            total_energy += (hc.mem_bytes * hb.mem_energy_per_byte
                             + hc.ops / hb.tops_per_watt)

    total_latency *= n_layers
    total_energy *= n_layers
    return {
        "latency_s": total_latency,
        "energy_j": total_energy,
        "bank_times": bank_times_first_stage,
        "stages": plan.num_stages,
    }


def far_bank_transfer(nbytes: float, hb: HBConfig = HBConfig(),
                      *, hops: float | None = None) -> Dict:
    """Cost of moving ``nbytes`` between a bank's near tier (its stacked
    DRAM dies) and the far bank over the NoC — the hardware behind the
    serving engine's hot/cold page residency (spills, demand fills and
    prefetches; byte counts from runtime.perfmodel.tier_traffic_bytes).

    Latency is NoC-link bound (12.8 GB/s/link << 204.8 GB/s near-memory
    bandwidth); energy pays both memory endpoints (read source + write
    destination) plus the per-hop NoC energy. ``hops`` defaults to the
    mean Manhattan distance of the mesh grid — a documented assumption,
    like the hop energy itself."""
    if hops is None:
        gx, gy = hb.grid
        hops = (gx + gy) / 2.0
    latency = nbytes / hb.noc_link_bw
    energy = nbytes * (2 * hb.mem_energy_per_byte
                       + hops * hb.noc_energy_per_byte_hop)
    return {"latency_s": latency, "energy_j": energy, "hops": hops}


def tiered_serving_overhead(cfg: ArchConfig, *, fills: int, spills: int,
                            prefetch: int, decode_steps: int,
                            hb: HBConfig = HBConfig()) -> Dict:
    """Modeled far-bank overhead of a tiered serving run: converts the
    engine's page counters into blocking (demand-fill) and overlapped
    (prefetch + spill) transfer time and total energy, amortized per
    decode step. The blocking share is the model's prediction of what
    tiering costs when the prefetcher misses; the overlapped share rides
    under decode and costs only energy."""
    from repro.runtime import perfmodel

    traffic = perfmodel.tier_traffic_bytes(
        cfg, fills=fills, spills=spills, prefetch=prefetch)
    blocking = far_bank_transfer(traffic["blocking"], hb)
    overlapped = far_bank_transfer(traffic["total"] - traffic["blocking"],
                                   hb)
    steps = max(int(decode_steps), 1)
    return {
        "far_bytes": traffic["total"],
        "blocking_s": blocking["latency_s"],
        "overlapped_s": overlapped["latency_s"],
        "energy_j": blocking["energy_j"] + overlapped["energy_j"],
        "blocking_s_per_step": blocking["latency_s"] / steps,
    }


def rebalance_overhead(cfg: ArchConfig, *, migrations: int,
                       migrated_tokens: int, decode_steps: int,
                       hb: HBConfig = HBConfig()) -> Dict:
    """Modeled NoC cost of a rebalanced serving run: converts the
    engine's migration counters (EngineStats.migrations /
    migrated_tokens; byte model runtime.perfmodel.migration_traffic_bytes)
    into transfer time and energy, amortized per decode step. Migration
    runs between engine steps — never inside one — so the time is
    overlap-able link occupancy, not a decode stall; the cycle model
    prices what each migration costs against the per-bank imbalance it
    removes (EngineStats.imbalance_pre/post)."""
    from repro.runtime import perfmodel

    nbytes = perfmodel.migration_traffic_bytes(
        cfg, migrations=migrations, migrated_tokens=migrated_tokens)
    xfer = far_bank_transfer(nbytes, hb)
    steps = max(int(decode_steps), 1)
    return {
        "migration_bytes": nbytes,
        "transfer_s": xfer["latency_s"],
        "energy_j": xfer["energy_j"],
        "transfer_s_per_step": xfer["latency_s"] / steps,
    }


def gemm_decode(cfg: ArchConfig, hb: HBConfig = HBConfig()) -> Dict:
    """Non-attention (GEMM) cost of one decode token: weights are read
    once from the memory dies (batch=1 edge decode), compute on DCIM."""
    n = cfg.active_param_count()
    w_bytes = float(n)  # int8
    ops = 2.0 * n
    lat = max(w_bytes / hb.chip_mem_bw, ops / (hb.bank_tops * hb.banks))
    energy = w_bytes * hb.mem_energy_per_byte + ops / hb.tops_per_watt
    return {"latency_s": lat, "energy_j": energy}


def e2e_decode(cfg: ArchConfig, seq: int, mode: str,
               hb: HBConfig = HBConfig(),
               h2: H2ealConfig | None = None) -> Dict:
    att = attention_decode(cfg, seq, mode, hb, h2)
    gem = gemm_decode(cfg, hb)
    lat = att["latency_s"] + gem["latency_s"]
    en = att["energy_j"] + gem["energy_j"]
    return {
        "latency_s": lat,
        "tokens_per_s": 1.0 / lat,
        "tokens_per_j": 1.0 / en,
        "attention_s": att["latency_s"],
        "gemm_s": gem["latency_s"],
    }
