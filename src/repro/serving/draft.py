"""Draft providers for self-drafted speculative decoding (PR 8).

A ``DraftProvider`` proposes ``k - 1`` continuation tokens per active
slot each verify step; the engine prepends the slot's pending feed token
and verifies all ``k`` positions in ONE chunked forward
(runtime/serve.make_verify_step). Losslessness never depends on the
draft — the coupled rejection sampler emits exactly the tokens the
non-speculative engine would for ANY proposal — so providers only trade
acceptance rate against draft cost:

  * ``NgramDraft`` — host-side prompt-lookup (suffix n-gram match over
    the request's prompt + emitted history). Model-free, deterministic,
    zero device work: the test workhorse.
  * ``StreamingDraft`` — self-draft: runs the decode body on a throwaway
    copy of the serve state whose retrieval-head page selection is
    masked out (``sel_idx = -1``), i.e. the model drafting with its own
    streaming (sink + local) heads only — the H²EAL sparse skeleton as
    its own cheap draft model. k-1 chained greedy reuse steps, no
    selection refresh, caches mutated only on the copy.
  * ``ConstantDraft`` / ``ReplayDraft`` — test doubles forcing the
    all-reject (degenerates to the baseline one-token step) and
    all-accept (replay a baseline run's trace) extremes
    (tests/test_sampling.py).

Providers that set ``needs_host_tokens`` get a per-slot host token
history (prompt + every emitted token) maintained by the engine; the
rest work from device state alone.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _cache_size(fn) -> int:
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class DraftProvider:
    """Interface: propose ``(B, k-1)`` draft tokens for the active slots.

    ``draft`` may return a numpy array or a device array; rows of
    inactive slots are ignored. ``needs_host_tokens`` asks the engine to
    maintain ``engine._spec_history[slot]`` (prompt + emitted tokens,
    including the pending feed token as the last element).
    """

    name = "base"
    needs_host_tokens = False

    def draft(self, engine, active: np.ndarray, k: int):
        raise NotImplementedError

    def jit_cache_sizes(self) -> Dict[str, int]:
        """Compiled-entry counts of any jits the provider owns (merged
        into Engine.jit_cache_sizes() for the zero-recompile check)."""
        return {}


class NgramDraft(DraftProvider):
    """Prompt-lookup drafting: match the longest recent suffix n-gram
    (n = max_n .. 1) of the slot's history against an earlier occurrence
    and propose the tokens that followed it; pad by repeating the last
    proposed (or feed) token. Pure host work, fully deterministic."""

    name = "ngram"
    needs_host_tokens = True

    def __init__(self, max_n: int = 3):
        self.max_n = max(int(max_n), 1)

    def _lookup(self, hist: Sequence[int], m: int) -> List[int]:
        hist = list(hist)
        cont: List[int] = []
        for n in range(min(self.max_n, len(hist) - 1), 0, -1):
            suffix = hist[-n:]
            # most recent EARLIER occurrence of the suffix
            for i in range(len(hist) - n - 1, -1, -1):
                if hist[i:i + n] == suffix:
                    cont = hist[i + n:i + n + m]
                    break
            if cont:
                break
        pad = cont[-1] if cont else hist[-1]
        while len(cont) < m:
            cont.append(pad)
        return cont[:m]

    def draft(self, engine, active: np.ndarray, k: int):
        b = engine.batch
        out = np.zeros((b.max_batch, max(k - 1, 0)), np.int32)
        if k <= 1:
            return out
        for slot in np.nonzero(active)[0]:
            slot = int(slot)
            out[slot] = self._lookup(engine._spec_history[slot], k - 1)
        return out


class StreamingDraft(DraftProvider):
    """Self-draft with the model's own streaming heads: decode ``k - 1``
    greedy tokens on a copy of the serve state whose retrieval-head page
    selection is masked to the -1 sentinel — retrieval heads then attend
    to sink + local pages only (core/paging.token_validity drops
    negative slots), which is exactly the model restricted to its
    streaming skeleton. The copy is discarded after drafting; the real
    state is never touched, so the verify step sees pristine pre-append
    caches."""

    name = "streaming"
    needs_host_tokens = False

    def __init__(self):
        self._owner = None
        self._mask = None
        self._dec = None

    def _bind(self, engine):
        if self._owner is engine:
            return
        if self._owner is not None:
            raise ValueError(
                "a StreamingDraft instance serves one engine (its jit "
                "caches are engine-private); build a fresh one")
        from repro.runtime import serve as serve_rt

        scfg = serve_rt.ServeConfig(capacity=engine.cache_capacity,
                                    layout=engine.layout,
                                    impl=engine.attn_impl)
        dec_fn = serve_rt.make_ragged_decode_step(engine.cfg, scfg,
                                                  do_select=False)

        def masked_copy(state):
            def leaf(path, x):
                if jax.tree_util.keystr(path).endswith(".sel_idx"):
                    return jnp.full_like(x, -1)
                return x
            return jax.tree_util.tree_map_with_path(leaf, state)

        # the mask jit COPIES (no donation — the real state stays live
        # for the verify step); the chained decode donates the copy
        self._mask = jax.jit(masked_copy, **engine._state_out_shard)
        self._dec = jax.jit(dec_fn, donate_argnums=(1,),
                            **engine._dec_out_shard)
        self._owner = engine

    def draft(self, engine, active: np.ndarray, k: int):
        if k <= 1:
            return np.zeros((engine.batch.max_batch, 0), np.int32)
        self._bind(engine)
        act = jnp.asarray(active)
        state = self._mask(engine.batch.serve)
        tok = engine._tok
        cols = []
        for _ in range(k - 1):
            logits, state = self._dec(engine.params, state, tok, act)
            tok = jnp.where(act,
                            jnp.argmax(logits, axis=-1).astype(jnp.int32),
                            tok)
            cols.append(tok)
        return jnp.stack(cols, axis=1)

    def jit_cache_sizes(self) -> Dict[str, int]:
        if self._owner is None:
            return {}
        return {"mask": _cache_size(self._mask),
                "decode": _cache_size(self._dec)}


class ConstantDraft(DraftProvider):
    """Test double: a constant (by default invalid) draft token — every
    position rejects, so each verify step accepts exactly the one
    coupled target and the engine degenerates to the baseline
    one-token-per-step trajectory."""

    name = "constant"

    def __init__(self, token: int = -1):
        self.token = int(token)

    def draft(self, engine, active: np.ndarray, k: int):
        return np.full((engine.batch.max_batch, max(k - 1, 0)),
                       self.token, np.int32)


class ReplayDraft(DraftProvider):
    """Test double: replay an oracle continuation per uid (e.g. the
    token trace of a baseline non-speculative run) — under greedy every
    draft position matches its coupled target, forcing the all-accept
    path up to the engine's ``max_emit`` clamps."""

    name = "replay"

    def __init__(self, oracle: Dict[int, Sequence[int]]):
        self.oracle = {int(u): [int(t) for t in toks]
                       for u, toks in oracle.items()}

    def draft(self, engine, active: np.ndarray, k: int):
        b = engine.batch
        out = np.full((b.max_batch, max(k - 1, 0)), -1, np.int32)
        if k <= 1:
            return out
        for slot in np.nonzero(active)[0]:
            slot = int(slot)
            toks = self.oracle.get(int(b.uid[slot]))
            if toks is None:
                continue
            # tokens emitted so far (incl. the prefill token) index the
            # oracle: the feed token is oracle[emitted-1], so the draft
            # continues at oracle[emitted]
            emitted = int(engine._spec_emitted[slot])
            cont = toks[emitted:emitted + (k - 1)]
            out[slot, :len(cont)] = cont
        return out


_BUILTINS = {"ngram": NgramDraft, "streaming": StreamingDraft}


def resolve_draft(spec) -> DraftProvider:
    """Resolve ``Engine(draft=...)``: a provider instance passes
    through; a name builds the builtin (``ngram`` | ``streaming``)."""
    if isinstance(spec, DraftProvider):
        return spec
    if isinstance(spec, str) and spec in _BUILTINS:
        return _BUILTINS[spec]()
    raise ValueError(
        f"unknown draft provider {spec!r}; builtins: "
        f"{sorted(_BUILTINS)} (or pass a DraftProvider instance)")
