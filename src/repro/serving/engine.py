"""Slot-based continuous batching over the compiled H²EAL step triple.

The lockstep loop in ``launch/serve.py`` forces every request in a batch
to share one prompt length and one generation length — exactly the
workload imbalance the paper's load-balancing scheduler (§IV-C) targets
at the bank level, replayed at the batch level. This engine removes the
lockstep:

  * ``BatchState`` holds a **fixed max-batch** compiled decode shape:
    per-slot caches, a per-slot ``length`` (B,) vector threaded through
    cache appends / attention validity (core/cache.py,
    core/hybrid_attention.py), a per-slot ``active`` mask, a per-slot
    ``prefilling`` mask, and a per-slot share-window ``phase``.
  * Admission comes in two modes. **Chunked** (``prefill_chunk=N``, the
    production path): a request is admitted to a free slot IMMEDIATELY
    in a ``PREFILLING`` phase — the slot's cache rows are cleared to the
    empty sentinels by one donated dynamic-slot reset, and each engine
    step feeds up to ``N`` prompt tokens (one STATIC chunk-size bucket,
    per-slot lengths dynamic) **directly into the slot's rows of the
    batched sharded state** through the layout protocol
    (core/layouts.py ``prefill_chunk``), interleaved with the normal
    ragged decode of every other slot. No decode slot ever stalls for a
    prompt: time-to-first-token is bounded by ceil(S/N) engine steps
    and inter-token latency by one chunk's compute, regardless of
    prompt length. **Prefill-then-pack** (``prefill_chunk=None``): the
    legacy monolithic admission — batch-1 prefill (one compile per
    prompt bucket) packed into a free slot with a donated
    ``dynamic_update_slice`` tree op; kept as the token-exactness
    oracle chunked admission is tested against. Recurrent mixers
    (mamba2/xlstm) resume their per-slot scan state across chunk
    boundaries (models/ssm.py, models/xlstm.py ``*_prefill_chunk``),
    so chunked admission covers every mixer.
  * Retirement flips ``active`` off; the slot's caches stay bit-stable
    (appends are masked) until the next admission resets/overwrites
    them.
  * Page selection refreshes on each slot's OWN share-window cadence
    (``phase % w == 0`` — so a slot always selects on its first decode
    step), and the ``select`` variant applies the fresh selection
    **only** to slots whose refresh is due (``need_select`` blending).
    A slot's refresh schedule is therefore a function of its own phase
    alone — its decode logits are invariant to other slots joining or
    leaving AND to how its own admission was scheduled (packed, or
    chunked at any chunk size); the co-placement exactness argument
    applied to continuous batching, tested in tests/test_serving.py.
  * The decode loop never blocks on the device: retirement is
    budget-driven, so generated tokens are left on device (one (B,)
    vector per step) and extracted once at the end of ``run()``
    (``finalize()``). The host loop dispatches steps back-to-back just
    like the lockstep driver.

After warmup (one prefill compile per prompt bucket + the two decode
variants + pack), the steady state runs with zero recompiles regardless
of how requests arrive — verified via jit cache-miss counts in
benchmarks/serve_throughput.py.

The engine runs under ANY layout registered in core/layouts.py
(AttentionLayout registry): the layout's ``plan()`` resolves and
validates the mesh, rounds the cache capacity, and decides whether the
batched state lives in a sharded placement — all at construction time,
so every layout gets the same early validation. ``coplace_shmap``
(paper §IV-B: pages sharded over the mesh 'model' axis, each device
computing partial attention for exactly the pages it stores, merged
with a cross-device log-sum-exp combine — core/hybrid_attention.py)
and ``interleave`` (paper Fig 7b: GSPMD within-page token striping) are
the sharded entries; the per-slot length/active/need_select vectors
thread straight through either decode body, and
``admission="balanced"`` adds the paper's §IV-C load balancing at the
batch dimension: queued requests are admitted in the order that keeps
per-device page load flattest (sched/balance.py). See docs/serving.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.runtime import serve as serve_rt
from repro.serving import sampling as samplib


@dataclasses.dataclass
class Request:
    """One generation request. Under packed admission
    (``prefill_chunk=None``) the ``prompt`` length must be one of the
    engine's prompt buckets (pad upstream; the padded prompt is
    canonical). Chunked admission compiles per chunk bucket instead, so
    any length in ``[1, capacity)`` is admissible unpadded."""

    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    # per-request sampling policy (serving/sampling.py). Defaults are
    # greedy argmax — bit-identical to the pre-sampling engine. The RNG
    # key stream is owned by (seed, uid), never by the slot, so traces
    # are invariant to slot churn and admission order.
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int = 0


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: List[int]            # filled by Engine.finalize()
    admitted_step: int
    finished_step: int = -1
    first_token_step: int = -1    # EngineStats.engine_steps at first token
    admitted_engine_step: int = -1  # EngineStats.engine_steps at admission
    # device-side bookkeeping until finalize():
    _first_tok: object = None    # device scalar from the prefill logits
    _slot: int = -1
    _seq: int = -1               # admission sequence (FIFO chunk order)
    _step_idx: List[int] = dataclasses.field(default_factory=list)
    # slot index at which each trace row was emitted — recorded per row
    # (not derived from _slot at finalize) so live migration between
    # slot indices (Engine(rebalance=...)) never invalidates old rows
    _slot_idx: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class EngineStats:
    decode_steps: int = 0
    select_steps: int = 0
    reuse_steps: int = 0
    engine_steps: int = 0        # logical steps (a fused window counts
                                 # each of its in-scan steps)
    admissions: int = 0          # requests admitted into a slot
    prefill_chunks: int = 0      # chunked-prefill steps (mixed steps;
                                 # in-scan chunk iterations count too)
    tokens_out: int = 0
    occupancy_sum: float = 0.0   # sum over steps of live-slot fraction
    wall_s: float = 0.0          # set by run()
    admission_reorders: int = 0  # balanced admission: non-FIFO picks
    # dispatch accounting (PR 10): before fused windows, decode_steps
    # doubled as the dispatch count; a fused window collapses up to w-1
    # steps into ONE dispatch, so the two are split. ``dispatches``
    # counts every jitted call the engine issues (decode, sample,
    # prefill, pack/reset, chunk, tier ops, verify, migrate — draft-
    # provider internals excluded).
    dispatches: int = 0
    fused_windows: int = 0       # fused decode-window dispatches
    fused_steps: int = 0         # decode steps consumed inside them
    # tiered residency (Engine(hot_pages=N); all counts are PAGES):
    tier_hits: int = 0           # selected pages found device-resident
    tier_misses: int = 0         # selected pages cold — filled + replayed
    tier_spills: int = 0         # pages archived to the far store
    tier_fills: int = 0          # demand fills (miss repair)
    tier_prefetch: int = 0       # speculative fills one window ahead
    # batched tier transfers (PR 10): one refresh plan = one batched
    # fill + one batched spill dispatch across every (slot, page) pair
    tier_fill_batches: int = 0   # batched fill dispatches
    tier_spill_batches: int = 0  # batched spill dispatches
    tier_gather_batches: int = 0  # batched first-spill archive gathers
    tier_batch_pages_max: int = 0  # largest single batched transfer
    # speculative decode (Engine(spec_tokens=k)):
    spec_steps: int = 0          # verify dispatches (batched steps)
    spec_slot_steps: int = 0     # per-slot verify events (accept samples)
    spec_drafted: int = 0        # draft tokens proposed (k-1 per event)
    spec_accepted: int = 0       # tokens emitted by verify steps (>= 1 each)
    # dynamic rebalancing (Engine(rebalance=...); sched/rebalance.py):
    rebalance_checks: int = 0    # planner invocations (post-cooldown)
    rebalances: int = 0          # plans applied (>= 1 migration each)
    rebalance_skipped: int = 0   # triggers rejected (cooldown/hysteresis)
    migrations: int = 0          # slot moves executed
    migrated_tokens: int = 0     # context tokens moved (traffic model)
    imbalance_pre_sum: float = 0.0   # cost imbalance at each check
    imbalance_post_sum: float = 0.0  # ... after the applied plan (if any)

    @property
    def prefills(self) -> int:
        """Deprecated pre-chunking name: the old counter conflated
        compiles, admissions, and (now) chunks — read ``admissions``
        and ``prefill_chunks`` instead."""
        return self.admissions

    @property
    def occupancy(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def steps_per_s(self) -> float:
        """Decode-step rate. Identical to ``tokens_per_s`` per slot
        without speculation; under ``spec_tokens=k`` one verify step
        emits up to k tokens per slot, so the two rates split — report
        BOTH (the PR-8 stats fix; benchmarks/serve_throughput.py)."""
        return self.decode_steps / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def engine_steps_per_s(self) -> float:
        """Logical engine-step rate (the PR-10 stats fix: decode_steps
        conflated steps with dispatches once windows fuse — this is the
        step rate, ``steps_per_dispatch`` is the fusion factor)."""
        return self.engine_steps / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def steps_per_dispatch(self) -> float:
        """Decode steps per jitted dispatch — the directly-observable
        dispatch reduction of fused decode windows (~1/2 per-step: each
        decode step costs a decode + a sample dispatch; up to ~w-1 of a
        share window rides one fused dispatch)."""
        return (self.decode_steps / self.dispatches
                if self.dispatches else 0.0)

    @property
    def tier_fill_batch_mean(self) -> float:
        """Mean pages per batched tier fill (demand + prefetch)."""
        return ((self.tier_fills + self.tier_prefetch)
                / self.tier_fill_batches if self.tier_fill_batches
                else 0.0)

    @property
    def tier_spill_batch_mean(self) -> float:
        """Mean pages per batched tier spill."""
        return (self.tier_spills / self.tier_spill_batches
                if self.tier_spill_batches else 0.0)

    @property
    def mean_accepted_len(self) -> float:
        """Mean tokens emitted per per-slot verify event (1.0 = every
        draft rejected; k = every draft accepted)."""
        return (self.spec_accepted / self.spec_slot_steps
                if self.spec_slot_steps else 0.0)

    @property
    def tier_hit_rate(self) -> float:
        seen = self.tier_hits + self.tier_misses
        return self.tier_hits / seen if seen else 1.0

    @property
    def imbalance_pre(self) -> float:
        """Mean max/mean device-compute imbalance AT rebalance checks
        (1.0 = perfectly balanced; 1.0 when no check ever ran)."""
        return (self.imbalance_pre_sum / self.rebalance_checks
                if self.rebalance_checks else 1.0)

    @property
    def imbalance_post(self) -> float:
        """Same checks, scored after the applied plan (equals the pre
        value whenever a check proposed no moves)."""
        return (self.imbalance_post_sum / self.rebalance_checks
                if self.rebalance_checks else 1.0)


@dataclasses.dataclass
class BatchState:
    """Host view of the batched serve state.

    ``serve`` is the device pytree (per-slot caches + (B,) length);
    the numpy arrays mirror per-slot scheduling metadata the host loop
    needs without device round-trips. A slot is in exactly one of four
    phases: FREE (no mask set), PREFILLING (``prefilling``; length
    counts prompt tokens fed so far), READY (``ready``; prompt done and
    first token emitted, waiting for the batch's shared refresh
    boundary), or DECODING (``active``).
    """

    serve: dict                  # model serve state, length: (B,) int32
    active: np.ndarray           # (B,) bool — decoding slots
    prefilling: np.ndarray       # (B,) bool — chunked-prefill slots
    ready: np.ndarray            # (B,) bool — awaiting phase-aligned start
    lengths: np.ndarray          # (B,) int64 — host mirror of serve length
    phase: np.ndarray            # (B,) int64 — decode steps since admission
    uid: np.ndarray              # (B,) int64 — -1 when free
    remaining: np.ndarray        # (B,) int64 — generation budget left
    prompt_left: np.ndarray      # (B,) int64 — prompt tokens not yet fed
    # per-slot sampling lanes (device arrays; serving/sampling.py). Rows
    # are (re)written eagerly at admission; ``samp_gen`` — the per-slot
    # generation index driving in-graph key derivation — additionally
    # advances inside the sample/verify jits.
    samp_base: jax.Array = None  # (B, 2) uint32 — request base keys
    samp_temp: jax.Array = None  # (B,) f32
    samp_topp: jax.Array = None  # (B,) f32
    samp_gen: jax.Array = None   # (B,) int32 — tokens sampled so far

    @property
    def max_batch(self) -> int:
        return self.active.shape[0]

    def free_slots(self) -> List[int]:
        return [i for i in range(self.max_batch)
                if not self.active[i] and not self.prefilling[i]
                and not self.ready[i]]


def jit_cache_size(fn) -> int:
    """Number of compiled entries behind a jax.jit function (recompile
    counter for the no-recompiles-after-warmup check); -1 if unknown."""
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


def _pack_slot(big: dict, small: dict, slot):
    """Write the batch-1 serve state ``small`` into slot ``slot`` of the
    batched state ``big``. Slot index is dynamic — one compile total.

    Leaf batch axis: 1 for scan-stacked "blocks" leaves, else 0;
    "length" is scalar in ``small`` and (B,) in ``big``.
    """
    def upd(path, bg, sm):
        ps = jax.tree_util.keystr(path)
        if ps.endswith("['length']"):
            return jax.lax.dynamic_update_slice(
                bg, jnp.reshape(sm, (1,)).astype(bg.dtype), (slot,))
        axis = 1 if "['blocks']" in ps else 0
        start = (0,) * axis + (slot,) + (0,) * (bg.ndim - axis - 1)
        return jax.lax.dynamic_update_slice(bg, sm.astype(bg.dtype), start)

    return jax.tree_util.tree_map_with_path(upd, big, small)


def _reset_slot(big: dict, slot):
    """Clear slot ``slot`` of the batched serve state to the EMPTY-cache
    sentinels (±inf page metadata, -1 page_start / ring positions, zeros
    elsewhere, length 0) — the state a fresh PagedCache/StreamCache
    constructor produces. Chunked admission starts from this clean row so
    no stale token of a previous occupant can pass a validity mask and
    the incremental chunk-append min/max metadata merge is exact. Slot
    index is dynamic — one compile total, mirroring ``_pack_slot``.
    """
    from repro.core import cache as cachelib

    def upd(path, bg):
        ps = jax.tree_util.keystr(path)
        if ps.endswith("['length']"):
            return jax.lax.dynamic_update_slice(
                bg, jnp.zeros((1,), bg.dtype), (slot,))
        axis = 1 if "['blocks']" in ps else 0
        row_shape = bg.shape[:axis] + (1,) + bg.shape[axis + 1:]
        row = jnp.full(row_shape, cachelib.empty_fill_value(ps), bg.dtype)
        start = (0,) * axis + (slot,) + (0,) * (bg.ndim - axis - 1)
        return jax.lax.dynamic_update_slice(bg, row, start)

    return jax.tree_util.tree_map_with_path(upd, big)


class Engine:
    """Continuous-batching engine. See module docstring.

    Parameters
    ----------
    cfg, params : model config + parameters.
    max_batch   : number of slots (the compiled decode batch).
    capacity    : max context tokens any slot may reach (cache size).
    prompt_buckets : allowed prompt lengths; one prefill compile each
                  (packed mode). Chunked mode compiles per CHUNK bucket,
                  not per prompt bucket, so any prompt length below
                  capacity is admissible — the buckets then only size
                  the state-shape probe and remain the benchmark's
                  workload vocabulary.
    prefill_chunk : per-step chunked-prefill token budget (the static
                  chunk-size bucket). None (default) = legacy
                  prefill-then-pack admission. With an int N, admission
                  is immediate (PREFILLING phase) and each engine step
                  feeds at most N prompt tokens across the prefilling
                  slots, interleaved with the decode of every other
                  slot — bounded time-to-first-token and no decode
                  stall on long prompts. Works with every mixer
                  (recurrent mixers resume their per-slot scan state);
                  requires token prompts (frontend-stub archs keep
                  packed admission).
    impl        : attention kernel implementation, ``"ref"`` (pure-jnp
                  oracle) or ``"pallas"`` (Pallas kernels; interpret mode
                  off-TPU). Validated and BAKED INTO the compiled step
                  functions here at construction — impl switching never
                  happens per step, so the zero-recompile invariant is
                  unaffected (docs/serving.md). Exposed as ``--attn-impl``
                  by launch/serve.py and benchmarks/serve_throughput.py.
    layout      : serve-cache layout name, resolved through the
                  core/layouts registry (unknown names raise listing the
                  registered layouts). ``None`` is a deprecated alias for
                  ``"default"``. The layout's ``plan()`` runs here at
                  construction: it resolves/validates the mesh, rounds
                  the cache capacity to the layout's quantum, and decides
                  whether the batched state is device_put into a sharded
                  placement — so a layout whose mesh requirements aren't
                  met fails NOW, not at the first decode step.
    mesh        : mesh override for sharded layouts (each layout builds
                  its own host-local default). Every jitted call runs
                  inside this mesh's context so shard_map / GSPMD paths
                  can see it.
    admission   : ``"fifo"`` (default) or ``"balanced"`` — balanced looks
                  at the first ``admit_lookahead`` queued requests and
                  admits the one that keeps per-device page load most
                  balanced (sched/balance.admission_score; the paper's
                  §IV-C balancing applied to the batch dimension). Under
                  a tiered engine the score caps each slot's pages at
                  ``hot_pages`` — admission scores hot-set size, not
                  total pages.
    hot_pages   : per-slot device-resident page budget enabling TIERED
                  residency (None = all-resident). Cold pages spill to
                  the host far store (the simulated HB far bank); the
                  engine prefetches the hottest cold pages one share
                  window ahead of each selection refresh, detects
                  selected-but-cold pages via the metadata-only
                  selection, and serves them late (fill + replay) —
                  token traces are bit-identical to the all-resident
                  engine (docs/serving.md §Tiered residency). Counted
                  in ``EngineStats.tier_*``.
    spec_tokens : draft length k enabling SPECULATIVE decoding: each
                  decode step drafts k-1 tokens per active slot
                  (serving/draft.py), verifies all k in ONE chunked
                  forward at the static (B, k) bucket (the PR-6
                  pre-append chunk path), and accepts via coupled
                  rejection sampling — lossless, so traces (greedy AND
                  stochastic) are identical to ``spec_tokens=None``
                  (docs/serving.md §Speculative decode). Only accepted
                  prefixes are ever appended (attend-before-append; tau
                  scatter-min/max is not invertible, so there is nothing
                  to roll back). Requires all-attention mixers, full
                  attention pattern, H²EAL enabled, token prompts, no
                  tiering, and 1 <= k <= h2eal.local (the verify chunk
                  tail must fit the local window).
    draft       : DraftProvider instance or builtin name — ``"ngram"``
                  (host prompt-lookup, deterministic, default) or
                  ``"streaming"`` (self-draft on the model's streaming
                  heads). Ignored without ``spec_tokens``.
    rebalance   : dynamic load rebalancing trigger — ``"off"`` (default),
                  ``"retire"`` (re-plan when a slot retires: the moment
                  drift appears), or ``"interval"`` (every
                  ``rebalance_interval`` engine steps). A triggered check
                  scores every live slot's next-step compute
                  (sched/cost.CostModel: streaming/retrieval head mix,
                  hot-capped page reads, spec-verify horizon, chunked
                  prefill backlog) and migrates slots into free indices
                  via greedy-LPT (sched/rebalance.plan_rebalance) when
                  that flattens per-bank compute by at least
                  ``rebalance_min_gain`` (hysteresis), at most once per
                  ``rebalance_cooldown`` engine steps. Migration copies
                  the slot's cache rows / sampling lanes / tier residency
                  verbatim through ONE donated jit with dynamic indices
                  — token traces are bit-exact and the zero-recompile
                  invariant holds (docs/serving.md §Rebalancing).
    rebalance_banks : bank count the compute loads aggregate over
                  (contiguous slot-index blocks — the batch-axis sharding
                  view). Default: the layout's ``balance_shards`` when
                  sharded, else one bank per two slots (capped at 4) so
                  LPT can pair heavy slots with light ones within a bank.
    decode_window : fused decode-window length w enabling ONE-dispatch
                  execution of the reuse steps between two selection
                  boundaries: a ``lax.scan`` over the reuse step body
                  with sampling folded in-scan and device-side
                  retirement via a sched-computed per-slot budget vector
                  (sched/windows.py) — the host learns of retirements
                  only at the window boundary, where READY admission and
                  rebalance checks already live. Token traces are
                  bit-identical to per-step dispatch (the scanned body
                  IS the per-step program). None/1 = per-step dispatch
                  (the default, unchanged). Composes with chunked
                  prefill (the prefilling slots' chunk schedule is
                  presimulated on the host and threaded through the
                  scan) and with tiered residency (reuse steps only read
                  pinned-resident pages, so a fused window can never
                  cold-miss — the selection step stays per-step and
                  handles miss-replay). INCOMPATIBLE with
                  ``spec_tokens`` (verify steps advance phases by
                  variable accepted counts; the per-step fallback must
                  be requested explicitly by passing decode_window=None)
                  — validated here, never a silent fallback. See
                  docs/serving.md §Fused decode windows.
    """

    def __init__(self, cfg: ArchConfig, params, *, max_batch: int,
                 capacity: int, prompt_buckets: Sequence[int],
                 impl: str = "ref", layout: Optional[str] = "default",
                 mesh=None, admission: str = "fifo",
                 admit_lookahead: int = 4,
                 balance_shards: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 hot_pages: Optional[int] = None,
                 spec_tokens: Optional[int] = None,
                 draft="ngram",
                 rebalance: str = "off",
                 rebalance_interval: int = 16,
                 rebalance_min_gain: float = 0.02,
                 rebalance_cooldown: int = 8,
                 rebalance_banks: Optional[int] = None,
                 decode_window: Optional[int] = None):
        from repro.core import layouts as layoutlib
        from repro.kernels.ops import resolve_impl

        self.cfg = cfg
        self.params = params
        self.attn_impl = resolve_impl(impl)   # raises on unknown impls
        self.layout = layoutlib.resolve_layout(layout)  # raises on unknown
        # construction-time layout planning: mesh resolution/validation,
        # capacity rounding, sharded-state requirements — every layout
        # (not just coplace_shmap) gets the same early validation
        self.plan = layoutlib.get_layout(self.layout).plan(cfg, mesh)
        self.mesh = self.plan.mesh
        assert admission in ("fifo", "balanced"), admission
        self.admission = admission
        self.admit_lookahead = max(int(admit_lookahead), 1)
        # shard count the balanced admission scores against; defaults to
        # the layout plan's (1 → FIFO). Override for an engine whose
        # pages are sharded externally (or in tests).
        self.balance_shards = balance_shards
        self.capacity = int(capacity)
        # the sharded cache needs a whole number of pages per device; the
        # retirement boundary stays at the caller's `capacity`
        self.cache_capacity = self.plan.round_capacity(self.capacity)
        self.prompt_buckets = tuple(sorted(int(b) for b in prompt_buckets))
        assert self.prompt_buckets, "need at least one prompt bucket"
        assert self.prompt_buckets[-1] < self.capacity, (
            f"largest prompt bucket {self.prompt_buckets[-1]} must leave "
            f"room to decode within capacity {self.capacity}")
        self.prefill_chunk = int(prefill_chunk) if prefill_chunk else None
        if self.prefill_chunk is not None:
            assert self.prefill_chunk >= 1, prefill_chunk
            if cfg.embed_frontend_stub:
                raise ValueError(
                    "chunked prefill feeds token chunks through the "
                    "embedding; frontend-stub archs (vlm/audio) need "
                    "prefill_chunk=None (prefill-then-pack)")
        self.share_window = max(cfg.h2eal.share_window, 1)
        self.spec_tokens = int(spec_tokens) if spec_tokens else None
        self.draft = None
        if self.spec_tokens is not None:
            from repro.configs.base import (ATTN_LOCAL_GLOBAL,
                                            MIXER_ATTENTION)
            from repro.serving import draft as draftlib
            # the verify chunk runs the attention decode body only: no
            # recurrent-mixer chunk resume, no local-global windows, and
            # the chunk tail must fit inside every later query's local
            # window (k <= h2eal.local — the no-extra-pages gather
            # argument in core/paging.verify_token_validity)
            if cfg.mixer_pattern and any(m != MIXER_ATTENTION
                                         for m in cfg.mixer_pattern):
                raise ValueError(
                    "spec_tokens requires all-attention mixers; "
                    f"mixer_pattern={cfg.mixer_pattern}")
            if cfg.attn_pattern == ATTN_LOCAL_GLOBAL:
                raise ValueError(
                    "spec_tokens requires the full attention pattern "
                    "(local_global windows have no verify-chunk path)")
            if not cfg.h2eal.enabled:
                raise ValueError("spec_tokens requires h2eal.enabled")
            if cfg.embed_frontend_stub:
                raise ValueError(
                    "spec_tokens feeds token chunks through the "
                    "embedding; frontend-stub archs are unsupported")
            if hot_pages:
                raise ValueError(
                    "spec_tokens is incompatible with tiered residency "
                    "(the verify jit donates its input state; miss "
                    "repair needs it preserved)")
            if not 1 <= self.spec_tokens <= cfg.h2eal.local:
                raise ValueError(
                    f"spec_tokens={self.spec_tokens} must be in "
                    f"[1, h2eal.local={cfg.h2eal.local}]")
            self.draft = draftlib.resolve_draft(draft)
        self.decode_window = 1 if decode_window is None else int(decode_window)
        if self.decode_window < 1:
            raise ValueError(
                f"decode_window={decode_window} must be >= 1 "
                "(1 == per-step dispatch)")
        if self.decode_window > 1 and self.spec_tokens is not None:
            # verify steps advance each slot's phase by a VARIABLE
            # accepted count, so a fixed-budget in-scan window cannot
            # encode the stop conditions. The per-step fallback must be
            # chosen by the caller, never silently substituted.
            raise ValueError(
                "decode_window > 1 is incompatible with spec_tokens "
                "(verify steps advance phases by variable accepted "
                "counts); pass decode_window=None for per-step dispatch")
        if rebalance not in ("off", "retire", "interval"):
            raise ValueError(
                f"rebalance={rebalance!r}: valid triggers are "
                "'off', 'retire', 'interval'")
        self.rebalance = rebalance
        self.rebalance_interval = max(int(rebalance_interval), 1)
        self.rebalance_min_gain = float(rebalance_min_gain)
        self.rebalance_cooldown = max(int(rebalance_cooldown), 0)
        if rebalance_banks is not None:
            self.rebalance_banks = min(max(int(rebalance_banks), 1),
                                       int(max_batch))
        else:
            # one bank per TWO slot indices: a bank block must hold at
            # least two slots for LPT to pair a heavy slot with a light
            # one (n_banks == max_batch degenerates to pure permutations
            # — zero gain, always rejected by hysteresis)
            nb = (self.plan.balance_shards if self.plan.balance_shards > 1
                  else max(min(int(max_batch) // 2, 4), 1))
            self.rebalance_banks = min(nb, int(max_batch))
        self._cost_model = None
        if self.rebalance != "off":
            from repro.sched.cost import CostModel
            self._cost_model = CostModel.from_config(
                cfg, hot_cap=int(hot_pages) if hot_pages else None,
                spec_tokens=int(spec_tokens) if spec_tokens else 0,
                chunk_budget=self.prefill_chunk or 0)
        self._rebalance_due = False
        self._last_rebalance_step = -(1 << 30)
        scfg = serve_rt.ServeConfig(capacity=self.cache_capacity,
                                    layout=self.layout, impl=self.attn_impl)
        self._prefill = jax.jit(serve_rt.make_prefill(cfg, scfg))
        self.batch = self._init_batch_state(max_batch)
        # Under a sharded layout the batched state must live in ONE stable
        # sharded placement from step 0: otherwise the first decode
        # reshards it (unsharded zeros in, sharded layout out) and
        # pack/decode each compile a second entry AFTER warmup. Pinning
        # out_shardings keeps every steady-state call on a single
        # compiled program — for the chunk/reset admission ops too.
        dec_shard = {}
        reset_shard = {}
        self.hot_pages = int(hot_pages) if hot_pages else None
        # _pack_slot/_reset_slot are module-level, and jax.jit keys its
        # cache on the wrapped callable: jitting them directly would share
        # one cache across every Engine in the process, so another
        # engine's state pytree (e.g. a recurrent mixer's scan state)
        # would show up in this engine's jit_cache_sizes() recompile
        # counter. A fresh per-instance wrapper keeps the cache private.
        def _pack_fn(big, small, slot):
            return _pack_slot(big, small, slot)

        def _reset_fn(big, slot):
            return _reset_slot(big, slot)
        if self.plan.shard_state:
            from repro.runtime import sharding as shardlib
            ss = self.plan.state_shardings(cfg, self.batch.serve,
                                           batch_size=max_batch)
            self.batch.serve = jax.device_put(self.batch.serve, ss)
            dec_shard = {"out_shardings":
                         shardlib.serve_step_out_shardings(self.mesh, ss)}
            reset_shard = {"out_shardings": ss}
            self._pack = jax.jit(_pack_fn, donate_argnums=(0,),
                                 out_shardings=ss)
        else:
            self._pack = jax.jit(_pack_fn, donate_argnums=(0,))
        # tiered mode keeps the select step's INPUT state alive: the
        # engine may have to fill cold-missed pages into it and replay
        # the same step (miss repair), so the select jit must not donate.
        # Reuse steps never miss (every page a reuse step reads is
        # pinned resident), so they keep the donation.
        sel_donate = {} if self.hot_pages else {"donate_argnums": (1,)}
        self._dec_sel = jax.jit(
            serve_rt.make_ragged_decode_step(cfg, scfg, do_select=True),
            **sel_donate, **dec_shard)
        self._dec_reuse = jax.jit(
            serve_rt.make_ragged_decode_step(cfg, scfg, do_select=False),
            donate_argnums=(1,), **dec_shard)
        # per-slot sampling (always on; temp=0 rows take the argmax lane
        # bit-identically) + the speculative verify step (PR 8). Draft
        # providers reuse these out_shardings dicts for their own jits.
        self._dec_out_shard = dec_shard
        self._state_out_shard = reset_shard
        samp_shard = {}
        ver_shard = {}
        if self.plan.shard_state:
            rep = shardlib.replicated(self.mesh)
            samp_shard = {"out_shardings": (rep, rep)}
            ver_shard = {"out_shardings":
                         shardlib.verify_step_out_shardings(self.mesh, ss)}
        self._sample = jax.jit(serve_rt.make_sample_step(cfg, scfg),
                               **samp_shard)

        def _sample_one_fn(logits, base, gen, temp, topp):
            return samplib.sample_tokens(logits[None], base[None],
                                         gen[None], temp[None],
                                         topp[None])[0]
        self._sample_one = jax.jit(_sample_one_fn)
        # fused decode windows (PR 10): the reuse steps between two
        # selection boundaries collapse into ONE dispatched lax.scan
        # (runtime/serve.make_fused_window_step, routed through the
        # layout registry's decode_window hook). Built only when a
        # window can hold a reuse step at all (share_window > 1); the
        # selection step itself always stays per-step — it carries the
        # tiered miss-replay and the host-visible refresh digest.
        self._fused = None
        self._fused_mix = None
        self._fused_len = 0
        if self.decode_window > 1 and self.share_window > 1:
            self._fused_len = min(self.decode_window,
                                  self.share_window - 1)
            fw_shard = {}
            if self.plan.shard_state:
                fw_shard = {"out_shardings":
                            shardlib.fused_window_out_shardings(
                                self.mesh, ss)}
            self._fused = jax.jit(
                serve_rt.make_fused_window_step(
                    cfg, scfg, window=self._fused_len),
                donate_argnums=(1,), **fw_shard)
            if self.prefill_chunk is not None:
                self._fused_mix = jax.jit(
                    serve_rt.make_fused_window_step(
                        cfg, scfg, window=self._fused_len,
                        chunk=self.prefill_chunk),
                    donate_argnums=(1,), **fw_shard)
        self._migrate = None
        if self.rebalance != "off":
            # live slot migration (sched/rebalance.py): copy every
            # serve-state row src→dst (the _pack_slot leaf-axis
            # conventions), clear src to the empty sentinels (the
            # _reset_slot body), and move the sampling lanes + pending
            # token feed alongside — ONE donated jit with dynamic
            # indices, so any number of moves reuses a single compiled
            # entry. The token feed is NOT donated: _trace rows alias
            # the same array and finalize() reads them later.
            def _migrate_fn(big, tok, base, temp, topp, gen, src, dst):
                def move(path, bg):
                    ps = jax.tree_util.keystr(path)
                    if ps.endswith("['length']"):
                        row = jax.lax.dynamic_slice(bg, (src,), (1,))
                        return jax.lax.dynamic_update_slice(bg, row,
                                                            (dst,))
                    axis = 1 if "['blocks']" in ps else 0
                    sizes = bg.shape[:axis] + (1,) + bg.shape[axis + 1:]
                    s0 = (0,) * axis + (src,) + (0,) * (bg.ndim - axis - 1)
                    d0 = (0,) * axis + (dst,) + (0,) * (bg.ndim - axis - 1)
                    row = jax.lax.dynamic_slice(bg, s0, sizes)
                    return jax.lax.dynamic_update_slice(bg, row, d0)
                big = jax.tree_util.tree_map_with_path(move, big)
                big = _reset_slot(big, src)

                def lane(a, fill=0):
                    row = jax.lax.dynamic_slice_in_dim(a, src, 1, 0)
                    a = jax.lax.dynamic_update_slice_in_dim(a, row, dst, 0)
                    return jax.lax.dynamic_update_slice_in_dim(
                        a, jnp.full(row.shape, fill, a.dtype), src, 0)
                return (big, lane(tok), lane(base), lane(temp),
                        lane(topp, 1), lane(gen))
            mig_shard = {}
            if self.plan.shard_state:
                mig_shard = {"out_shardings":
                             (ss, rep, rep, rep, rep, rep)}
            self._migrate = jax.jit(_migrate_fn,
                                    donate_argnums=(0, 2, 3, 4, 5),
                                    **mig_shard)
        self._samp_host: Dict[int, tuple] = {}   # slot -> (base, t, p)
        self._verify = None
        if self.spec_tokens is not None:
            self._verify = jax.jit(
                serve_rt.make_verify_step(cfg, scfg, k=self.spec_tokens),
                donate_argnums=(1,), **ver_shard)
            self._spec_history: Dict[int, List[int]] = {}
            self._spec_emitted = np.zeros((max_batch,), np.int64)
        self._tier = None
        self._tier_plan = None       # pending (need, sel, hotness) refresh
        if self.hot_pages is not None:
            self._init_tier(reset_shard)
        if self.prefill_chunk is not None:
            self._chunk = jax.jit(
                serve_rt.make_prefill_chunk_step(
                    cfg, scfg, chunk=self.prefill_chunk),
                donate_argnums=(1,), **dec_shard)
            self._reset = jax.jit(_reset_fn, donate_argnums=(0,),
                                  **reset_shard)
        self._tok = jnp.zeros((max_batch,), jnp.int32)   # next-token feed
        self._act_dev = jnp.zeros((max_batch,), bool)    # device active mask
        self._act_mirror = np.zeros((max_batch,), bool)  # host copy of it
        # device-side token trace: a list of (k, B) row BLOCKS (one row
        # per per-step decode, k rows per verify step, up to window rows
        # per fused window); finalize() concatenates. _trace_rows is the
        # running row count — Completion._step_idx indexes rows, so it
        # must never be derived from len(_trace) or decode_steps.
        self._trace: List[jax.Array] = []
        self._trace_rows = 0
        # engine_steps watermark from the previous step() — the interval
        # rebalance trigger fires on CROSSING a multiple of the interval
        # (identical to `% == 0` per-step; a fused window can jump past
        # the multiple without ever landing on it)
        self._prev_engine_steps = 0
        # engine-step index of each trace row: lets a latency harness map
        # token emissions (Completion._step_idx trace rows) to per-step
        # wall-clock timestamps (benchmarks/serve_throughput.py --arrival)
        self.trace_engine_steps: List[int] = []
        self._prompts: Dict[int, np.ndarray] = {}        # slot -> prompt
        self._admit_seq = 0                              # FIFO chunk order
        self._queue: deque[Request] = deque()
        self._live: Dict[int, Completion] = {}       # slot -> in-flight
        self.completions: Dict[int, Completion] = {}  # uid -> finished
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    # state construction
    # ------------------------------------------------------------------

    def _mesh_ctx(self):
        """Ambient-mesh context for jitted calls: the shard_map co-placement
        path discovers the mesh at trace time (runtime/hints.current_mesh),
        so every prefill/decode/pack dispatch runs inside it."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _init_batch_state(self, max_batch: int) -> BatchState:
        """All-free batched state. Cache contents are irrelevant until a
        slot is admitted (pack overwrites every leaf row), so zeros are
        fine — validity masks keep the math NaN-free."""
        cfg = self.cfg
        if cfg.embed_frontend_stub:
            probe = jax.ShapeDtypeStruct(
                (max_batch, self.prompt_buckets[0], cfg.d_model), jnp.float32)
        else:
            probe = jax.ShapeDtypeStruct(
                (max_batch, self.prompt_buckets[0]), jnp.int32)
        shapes = jax.eval_shape(
            lambda p, b: M.prefill(cfg, p, b, capacity=self.cache_capacity),
            self.params, probe)[1]
        serve = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        serve["length"] = jnp.zeros((max_batch,), jnp.int32)
        return BatchState(
            serve=serve,
            active=np.zeros((max_batch,), bool),
            prefilling=np.zeros((max_batch,), bool),
            ready=np.zeros((max_batch,), bool),
            lengths=np.zeros((max_batch,), np.int64),
            phase=np.zeros((max_batch,), np.int64),
            uid=np.full((max_batch,), -1, np.int64),
            remaining=np.zeros((max_batch,), np.int64),
            prompt_left=np.zeros((max_batch,), np.int64),
            samp_base=jnp.zeros((max_batch, 2), jnp.uint32),
            samp_temp=jnp.zeros((max_batch,), jnp.float32),
            samp_topp=jnp.ones((max_batch,), jnp.float32),
            samp_gen=jnp.zeros((max_batch,), jnp.int32),
        )

    # ------------------------------------------------------------------
    # tiered residency (hot/cold KV pages; core/cache.TieredPagedCache)
    # ------------------------------------------------------------------

    def _init_tier(self, reset_shard: dict):
        from repro.core import cache as cachelib

        flat = jax.tree_util.tree_flatten_with_path(self.batch.serve)[0]
        kv = [(jax.tree_util.keystr(p), leaf) for p, leaf in flat
              if jax.tree_util.keystr(p).endswith(".k_pages")]
        if not kv:
            raise ValueError(
                "hot_pages tiering requires a paged retrieval-head cache; "
                "this config's serve state has no k_pages leaves")
        ps, leaf = kv[0]
        n_pages = leaf.shape[cachelib._leaf_batch_axis(ps) + 2]
        if not 1 <= self.hot_pages <= n_pages:
            raise ValueError(
                f"hot_pages={self.hot_pages} must be in [1, {n_pages}] "
                f"(cache capacity {self.cache_capacity} holds {n_pages} "
                f"pages of {self.cfg.h2eal.page_size})")
        h2 = self.cfg.h2eal
        self._tier = cachelib.TieredPagedCache(
            n_slots=self.batch.max_batch, n_pages=n_pages,
            hot_pages=self.hot_pages, page_size=h2.page_size,
            sink=h2.sink, local=h2.local,
            stripe_shards=self.plan.page_stripe_shards)

        # every batched transfer pads its (slot, page) pair vectors to
        # ONE static capacity, so any refresh plan — one page or the
        # whole cache — reuses a single compiled entry per op
        self._tier_pair_cap = self.batch.max_batch * n_pages

        # per-instance wrappers: keep each engine's jit caches private
        # (the _pack_fn rationale above)
        def _gather_fn(state, slots, pages):
            return cachelib.gather_kv_rows_pairs(state, slots, pages)

        def _spill_fn(state, slots, pages):
            return cachelib.spill_kv_rows_pairs(state, slots, pages)

        def _fill_fn(state, slots, pages, rows):
            return cachelib.fill_kv_rows_pairs(state, slots, pages, rows)

        self._tier_gather = jax.jit(_gather_fn)
        self._tier_spill = jax.jit(_spill_fn, donate_argnums=(0,),
                                   **reset_shard)
        self._tier_fill = jax.jit(_fill_fn, donate_argnums=(0,),
                                  **reset_shard)

    def _tier_digest(self, serve, need: np.ndarray):
        """Read back the fresh selection + accumulated page hotness for
        the slots that refreshed this step (one device_get per select
        step — the only host sync tiering adds). Returns
        ``(sel_by_slot, hot_by_slot)``: physical page-index sets and
        (n_pages,) importance sums, summed over layers and heads."""
        t = self._tier
        flat = jax.tree_util.tree_flatten_with_path(serve)[0]
        sel_leaves, imp_leaves = {}, {}
        for path, leaf in flat:
            ps = jax.tree_util.keystr(path)
            if ps.endswith(".sel_idx"):
                sel_leaves[ps] = leaf
            elif ps.endswith(".importance"):
                imp_leaves[ps] = leaf
        got_sel, got_imp = jax.device_get((sel_leaves, imp_leaves))
        sel_by, hot_by = {}, {}
        for slot in np.nonzero(need)[0]:
            slot = int(slot)
            sel: set = set()
            for ps, a in got_sel.items():
                ax = 1 if "['blocks']" in ps else 0
                v = np.moveaxis(a, ax, 0)[slot]
                sel.update(int(x) for x in v.ravel()
                           if 0 <= x < t.n_pages)
            hot = np.zeros((t.n_pages,), np.float64)
            for ps, a in got_imp.items():
                ax = 1 if "['blocks']" in ps else 0
                v = np.moveaxis(a, ax, 0)[slot]
                hot += np.asarray(v, np.float64).reshape(-1, t.n_pages
                                                         ).sum(axis=0)
            sel_by[slot], hot_by[slot] = sel, hot
        return sel_by, hot_by

    def _tier_pair_vectors(self, pairs):
        """(slot, page) pairs padded (-1) to the static pair capacity —
        one compiled entry per transfer op regardless of batch size."""
        m = self._tier_pair_cap
        assert len(pairs) <= m, (len(pairs), m)
        slots = np.full((m,), -1, np.int32)
        pages = np.full((m,), -1, np.int32)
        for i, (s, p) in enumerate(pairs):
            slots[i] = s
            pages[i] = p
        return jnp.asarray(slots), jnp.asarray(pages)

    def _tier_fill_work(self, serve, work, *, prefetch: bool):
        """Restore far-store rows onto the device for EVERY (slot, pages)
        entry of ``work`` in ONE batched scatter (demand fill on a cold
        miss, or speculative prefetch one share window ahead). Every
        filled page was spilled earlier, so its rows are in the far
        store by construction."""
        t = self._tier
        pairs = [(int(s), int(p)) for s, pg in work for p in pg]
        slots, pages = self._tier_pair_vectors(pairs)
        template = t.far[pairs[0]]
        rows = {ps: np.zeros((self._tier_pair_cap,) + r.shape, r.dtype)
                for ps, r in template.items()}
        for i, key in enumerate(pairs):
            for ps, r in t.far[key].items():
                rows[ps][i] = r
        serve = self._tier_fill(
            serve, slots, pages,
            {ps: jnp.asarray(v) for ps, v in rows.items()})
        self.stats.dispatches += 1
        self.stats.tier_fill_batches += 1
        self.stats.tier_batch_pages_max = max(
            self.stats.tier_batch_pages_max, len(pairs))
        for s, p in pairs:
            t.resident[s, p] = True
        if prefetch:
            self.stats.tier_prefetch += len(pairs)
        else:
            self.stats.tier_fills += len(pairs)
        return serve

    def _tier_spill_work(self, serve, work):
        """Archive EVERY (slot, pages) entry of ``work`` to the far store
        (first spill of a page gathers its rows off device — one batched
        gather for all first-timers; later spills reuse the archived
        copy, complete pages never change) and zero the device rows in
        ONE batched scatter."""
        t = self._tier
        pairs = [(int(s), int(p)) for s, pg in work for p in pg]
        to_gather = [key for key in pairs if key not in t.far]
        if to_gather:
            gs, gp = self._tier_pair_vectors(to_gather)
            rows = jax.device_get(self._tier_gather(serve, gs, gp))
            self.stats.dispatches += 1
            self.stats.tier_gather_batches += 1
            t.store_pair_rows([s for s, _ in to_gather],
                              [p for _, p in to_gather], rows,
                              len(to_gather))
        slots, pages = self._tier_pair_vectors(pairs)
        serve = self._tier_spill(serve, slots, pages)
        self.stats.dispatches += 1
        self.stats.tier_spill_batches += 1
        self.stats.tier_batch_pages_max = max(
            self.stats.tier_batch_pages_max, len(pairs))
        for s, p in pairs:
            t.resident[s, p] = False
        self.stats.tier_spills += len(pairs)
        return serve

    def _tier_select(self, need: np.ndarray, need_dev, act_dev):
        """Tiered select step: dispatch (non-donated), read back the
        metadata-only selection, and — if any selected page is cold —
        fill it into the PRESERVED input state and replay the step.
        Selection depends only on tau metadata + page_start + q (never
        page contents), so the replayed selection is identical and the
        replayed attention is exactly the all-resident step: the miss is
        served late, never skipped."""
        b = self.batch
        logits, serve2 = self._dec_sel(self.params, b.serve, self._tok,
                                       act_dev, need_dev)
        self.stats.dispatches += 1
        sel_by, hot_by = self._tier_digest(serve2, need)
        miss_work = []
        for slot in np.nonzero(need)[0]:
            slot = int(slot)
            missing = self._tier.missing(slot, sel_by[slot])
            self.stats.tier_hits += len(sel_by[slot]) - len(missing)
            self.stats.tier_misses += len(missing)
            if missing:
                miss_work.append((slot, missing))
        if miss_work:
            # every missed slot's repair rides ONE batched fill (PR 10)
            b.serve = self._tier_fill_work(b.serve, miss_work,
                                           prefetch=False)
            logits, serve2 = self._dec_sel(self.params, b.serve,
                                           self._tok, act_dev, need_dev)
            self.stats.dispatches += 1
        self._tier_plan = (need.copy(), sel_by, hot_by)
        return logits, serve2

    def _tier_refresh(self):
        """Post-step residency refresh for the slots that just selected:
        prefetch the hottest cold pages (one share window ahead of their
        NEXT selection) and spill resident candidates that fell out of
        the hot set."""
        need, sel_by, hot_by = self._tier_plan
        self._tier_plan = None
        b = self.batch
        fill_work, spill_work = [], []
        for slot in np.nonzero(need)[0]:
            slot = int(slot)
            if not b.active[slot]:          # retired this step
                continue
            to_fill, to_spill = self._tier.plan_refresh(
                slot, int(b.lengths[slot]), sel_by[slot], hot_by[slot])
            if to_fill:
                fill_work.append((slot, to_fill))
            if to_spill:
                spill_work.append((slot, to_spill))
        # the whole refresh plan rides ONE batched gather-fill and ONE
        # batched spill across every (slot, page) pair (PR 10) — the
        # per-slot per-op dispatch storm was the tiered engine's largest
        # fixed cost at small page counts
        if fill_work:
            b.serve = self._tier_fill_work(b.serve, fill_work,
                                           prefetch=True)
        if spill_work:
            b.serve = self._tier_spill_work(b.serve, spill_work)

    def tier_force_spill(self, uid: int) -> int:
        """Test/chaos hook: spill EVERY complete non-sink page of
        ``uid``'s slot — including the currently selected ones — so the
        slot's next selection refresh is guaranteed to cold-miss. Only
        legal when that refresh is the slot's next decode step
        (``phase % w == 0``): between refreshes the current selection is
        read by reuse steps, which must never see a cold page. Returns
        the number of pages spilled."""
        if self._tier is None:
            raise ValueError("tier_force_spill requires Engine(hot_pages=N)")
        slots = [s for s, c in self._live.items() if c.uid == uid]
        if not slots:
            raise ValueError(f"uid {uid} is not live")
        slot = slots[0]
        b = self.batch
        if not b.active[slot]:
            raise ValueError(f"uid {uid} is not decoding yet")
        if b.phase[slot] % self.share_window != 0:
            raise ValueError(
                "tier_force_spill is only legal at a selection boundary "
                f"(slot phase {int(b.phase[slot])} % "
                f"{self.share_window} != 0)")
        t = self._tier
        pages = [p for p in t.spill_candidates(slot, int(b.lengths[slot]),
                                               selected=set())
                 if t.resident[slot, p]]
        if pages:
            with self._mesh_ctx():
                b.serve = self._tier_spill_work(b.serve, [(slot, pages)])
        return len(pages)

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        if self.prefill_chunk is None:
            if len(req.prompt) not in self.prompt_buckets:
                raise ValueError(
                    f"prompt length {len(req.prompt)} not in buckets "
                    f"{self.prompt_buckets}; pad upstream")
        elif not 1 <= len(req.prompt) < self.capacity:
            # chunked admission compiles per CHUNK bucket, so any prompt
            # that leaves room to decode is admissible without padding
            raise ValueError(
                f"prompt length {len(req.prompt)} must be in "
                f"[1, capacity={self.capacity})")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new} "
                             f"(every admitted request emits at least the "
                             f"prefill token)")
        samplib.SamplingParams(temperature=req.temperature,
                               top_p=req.top_p, seed=req.seed).validate()
        self._queue.append(req)

    def _set_sampling(self, req: Request, slot: int):
        """Install the request's sampling lanes into slot ``slot``: the
        base key is a pure function of (seed, uid) — never of the slot —
        so the key stream (and hence any stochastic trace) is invariant
        to slot churn and admission order."""
        base = samplib.request_key(req.seed, req.uid)
        b = self.batch
        b.samp_base = b.samp_base.at[slot].set(base)
        b.samp_temp = b.samp_temp.at[slot].set(req.temperature)
        b.samp_topp = b.samp_topp.at[slot].set(req.top_p)
        b.samp_gen = b.samp_gen.at[slot].set(0)
        self._samp_host[slot] = (base, float(req.temperature),
                                 float(req.top_p))
        if self.spec_tokens is not None and self.draft.needs_host_tokens:
            self._spec_history[slot] = [int(t) for t in
                                        np.asarray(req.prompt)]

    def _first_token(self, slot: int, logits_row):
        """Sample the request's first token (generation index 0) from
        the prefill logits row and advance the slot's generation index."""
        base, temp, topp = self._samp_host[slot]
        first = self._sample_one(logits_row, base, 0, temp, topp)
        self.stats.dispatches += 1
        b = self.batch
        b.samp_gen = b.samp_gen.at[slot].set(1)
        self._tok = self._tok.at[slot].set(first)
        if self.spec_tokens is not None:
            self._spec_emitted[slot] = 1
            if self.draft.needs_host_tokens:
                self._spec_history[slot].append(int(jax.device_get(first)))
        return first

    def _new_completion(self, req: Request, slot: int) -> Completion:
        comp = Completion(uid=req.uid, prompt_len=len(req.prompt),
                          tokens=[],
                          admitted_step=self.stats.decode_steps)
        comp.admitted_engine_step = self.stats.engine_steps
        comp._slot = slot
        comp._seq = self._admit_seq
        self._admit_seq += 1
        self._live[slot] = comp
        self.stats.admissions += 1
        return comp

    def _admit_one(self, req: Request, slot: int):
        """Packed admission: batch-1 prefill + pack; the slot's first
        token is already emitted and it enters READY — it starts
        decoding at the batch's next shared refresh boundary
        (``_promote_ready``), so every active slot's phase stays aligned
        mod the share window."""
        prompt = jnp.asarray(np.asarray(req.prompt)[None])  # (1, S)
        self._set_sampling(req, slot)
        with self._mesh_ctx():
            logits, small = self._prefill(self.params, prompt)
            self.batch.serve = self._pack(self.batch.serve, small,
                                          jnp.int32(slot))
            self.stats.dispatches += 2          # prefill + pack
            first = self._first_token(slot, logits[0])
        if self._tier is not None:
            self._tier.reset_slot(slot)   # pack rewrote every device row
        b = self.batch
        b.ready[slot] = True
        b.lengths[slot] = len(req.prompt)
        b.phase[slot] = 0          # select on the slot's first decode step
        b.uid[slot] = req.uid
        comp = self._new_completion(req, slot)
        comp._first_tok = first
        # packed admission runs between engine steps: the prefill that
        # produced this token completes with the NEXT step's device work
        # (latency harnesses map first_token_step to per-step wall time)
        comp.first_token_step = self.stats.engine_steps + 1
        self.stats.tokens_out += 1
        b.remaining[slot] = req.max_new - 1
        # next append writes at position lengths[slot]; valid while < capacity
        if b.remaining[slot] <= 0 or b.lengths[slot] >= self.capacity:
            self._retire(slot)

    def _admit_one_chunked(self, req: Request, slot: int):
        """Chunked admission: the slot enters the PREFILLING phase
        immediately; its cache rows are cleared to the empty sentinels
        and subsequent engine steps feed the prompt chunk by chunk."""
        b = self.batch
        self._set_sampling(req, slot)
        with self._mesh_ctx():
            b.serve = self._reset(b.serve, jnp.int32(slot))
            self.stats.dispatches += 1
        if self._tier is not None:
            self._tier.reset_slot(slot)   # reset cleared every device row
        b.prefilling[slot] = True
        b.lengths[slot] = 0
        b.phase[slot] = 0
        b.uid[slot] = req.uid
        b.remaining[slot] = req.max_new
        b.prompt_left[slot] = len(req.prompt)
        self._prompts[slot] = np.asarray(req.prompt, np.int32)
        self._new_completion(req, slot)

    def _finish_prefill(self, slot: int, chunk_logits):
        """The chunk that just ran completed this slot's prompt: emit the
        first token from its logits row and flip the slot to READY — it
        starts decoding at the batch's next shared refresh boundary
        (``_promote_ready``), keeping all active phases aligned."""
        b = self.batch
        b.prefilling[slot] = False
        first = self._first_token(slot, chunk_logits[slot])
        b.ready[slot] = True
        b.phase[slot] = 0          # select on the slot's first decode step
        comp = self._live[slot]
        comp._first_tok = first
        comp.first_token_step = self.stats.engine_steps
        self._prompts.pop(slot, None)
        self.stats.tokens_out += 1
        b.remaining[slot] -= 1
        if b.remaining[slot] <= 0 or b.lengths[slot] >= self.capacity:
            self._retire(slot)

    def _finish_prefill_fused(self, slot: int, trace_blk, j: int,
                              engine_step: int):
        """In-scan prompt completion: iteration ``j`` of a fused window
        fed this slot's last prompt tokens and sampled its first token
        in-graph from the chunk logits (generation index 0 — the same
        row-wise sampling lane as ``_first_token``). The decode half
        never writes non-active rows, so trace row ``j`` still holds
        that token when the window returns. Host side mirrors
        ``_finish_prefill``: the slot flips to READY and joins decoding
        at the next shared refresh boundary."""
        b = self.batch
        b.prefilling[slot] = False
        b.ready[slot] = True
        b.phase[slot] = 0          # select on the slot's first decode step
        comp = self._live[slot]
        comp._first_tok = trace_blk[j, slot]
        comp.first_token_step = engine_step
        self._prompts.pop(slot, None)
        self.stats.tokens_out += 1
        b.remaining[slot] -= 1
        if b.remaining[slot] <= 0 or b.lengths[slot] >= self.capacity:
            self._retire(slot)

    def _retire(self, slot: int):
        b = self.batch
        b.active[slot] = False
        b.ready[slot] = False
        b.uid[slot] = -1
        b.remaining[slot] = 0
        if self._tier is not None:
            self._tier.reset_slot(slot)   # next occupant rewrites the rows
        self._samp_host.pop(slot, None)
        if self.spec_tokens is not None:
            self._spec_history.pop(slot, None)
        comp = self._live.pop(slot)
        comp.finished_step = self.stats.decode_steps
        self.completions[comp.uid] = comp
        if self.rebalance == "retire":
            # drift just appeared: re-plan at the END of this step (not
            # here — a retire can fire mid-step with a pending tier plan
            # and a captured active mask still in flight)
            self._rebalance_due = True

    def _pick_request(self) -> Request:
        """Next request to admit. FIFO by default; ``balanced`` scores the
        first ``admit_lookahead`` queued requests with the per-device
        page-load imbalance they would create next to the live slots
        (sched/balance.admission_score) and admits the best, FIFO on ties.
        """
        n_shards = self.balance_shards or self.plan.balance_shards
        if (self.admission != "balanced" or n_shards <= 1
                or len(self._queue) <= 1):
            return self._queue.popleft()
        from repro.sched import balance
        b = self.batch
        # score decoding/ready slots at the page load they WILL reach
        # (fed tokens + prompt still to come); PREFILLING slots go in as
        # (done, left) pairs so the score also sees the in-flight chunk
        # compute they and the candidate will contend for — a freshly
        # chunk-admitted long prompt shows length 0 but will occupy its
        # full page span within ceil(S/chunk) steps
        live, pre_done, pre_left = [], [], []
        for i in range(b.max_batch):
            if b.prefilling[i]:
                pre_done.append(int(b.lengths[i]))
                pre_left.append(int(b.prompt_left[i]))
            elif b.active[i] or b.ready[i]:
                live.append(int(b.lengths[i]) + int(b.prompt_left[i]))
        best_i, best_s = 0, None
        for i in range(min(self.admit_lookahead, len(self._queue))):
            s = balance.admission_score(
                live, len(self._queue[i].prompt), n_shards=n_shards,
                page_size=self.cfg.h2eal.page_size,
                hot_cap=self.hot_pages, spec_tokens=self.spec_tokens,
                prefill_done=pre_done, prefill_left=pre_left,
                chunk_budget=self.prefill_chunk)
            if best_s is None or s < best_s - 1e-12:
                best_i, best_s = i, s
        if best_i == 0:
            return self._queue.popleft()
        self.stats.admission_reorders += 1
        req = self._queue[best_i]
        del self._queue[best_i]
        return req

    def _admit(self):
        admit = (self._admit_one if self.prefill_chunk is None
                 else self._admit_one_chunked)
        for slot in self.batch.free_slots():
            if not self._queue:
                break
            admit(self._pick_request(), slot)

    # ------------------------------------------------------------------
    # the mixed prefill+decode step
    # ------------------------------------------------------------------

    def _chunk_shards(self) -> int:
        """Shard count the chunk allocator scores against (FIFO splits
        under anything but balanced admission)."""
        n = (self.balance_shards or self.plan.balance_shards
             if self.admission == "balanced" else 1)
        return max(n, 1)

    def _schedule_chunks(self):
        """Distribute this step's chunk budget over the prefilling slots.

        Returns (tokens (B, C) int32, chunk_len (B,) int32) or None when
        nothing is prefilling. FIFO by admission order; under
        ``admission="balanced"`` the split is page-granular and
        device-load aware (sched/balance.chunk_allocation scores which
        slot's next page lands on the least-loaded shard).
        """
        b = self.batch
        slots = [i for i in range(b.max_batch) if b.prefilling[i]]
        if not slots:
            return None
        from repro.sched import balance
        slots.sort(key=lambda i: self._live[i]._seq)
        alloc = balance.chunk_allocation(
            [int(b.lengths[i]) for i in slots],
            [int(b.prompt_left[i]) for i in slots],
            self.prefill_chunk, n_shards=self._chunk_shards(),
            page_size=self.cfg.h2eal.page_size)
        tokens = np.zeros((b.max_batch, self.prefill_chunk), np.int32)
        clens = np.zeros((b.max_batch,), np.int32)
        for i, n in zip(slots, alloc):
            if n <= 0:
                continue
            fed = int(b.lengths[i])
            tokens[i, :n] = self._prompts[i][fed:fed + n]
            clens[i] = n
        return tokens, clens

    def _plan_window_chunks(self, n_iters: int):
        """Presimulate the per-step chunk scheduler for ``n_iters``
        in-scan iterations WITHOUT touching the host mirrors: the
        allocator (sched/balance.chunk_allocation) is a deterministic
        function of (lengths, prompt_left) over the prefilling slots, so
        replaying it on local copies yields exactly the chunk blocks the
        per-step loop would feed — except that no admission can join
        mid-window (chunked-admission invariance keeps per-slot traces
        exact either way; docs/serving.md §Fused decode windows).
        Returns (tokens (L, B, C), clens (L, B), finish (L, B)) numpy
        arrays, or None when nothing is prefilling."""
        b = self.batch
        slots = [i for i in range(b.max_batch) if b.prefilling[i]]
        if not slots:
            return None
        from repro.sched import balance
        slots.sort(key=lambda i: self._live[i]._seq)
        n_shards = self._chunk_shards()
        chunk = self.prefill_chunk
        lengths = {i: int(b.lengths[i]) for i in slots}
        left = {i: int(b.prompt_left[i]) for i in slots}
        tokens = np.zeros((n_iters, b.max_batch, chunk), np.int32)
        clens = np.zeros((n_iters, b.max_batch), np.int32)
        finish = np.zeros((n_iters, b.max_batch), bool)
        for j in range(n_iters):
            live = [i for i in slots if left[i] > 0]
            if not live:
                break
            alloc = balance.chunk_allocation(
                [lengths[i] for i in live], [left[i] for i in live],
                chunk, n_shards=n_shards,
                page_size=self.cfg.h2eal.page_size)
            for i, n in zip(live, alloc):
                if n <= 0:
                    continue
                fed = lengths[i]
                tokens[j, i, :n] = self._prompts[i][fed:fed + n]
                clens[j, i] = n
                lengths[i] += n
                left[i] -= n
                if left[i] == 0:
                    # leaves the pool: READY slots take no more chunks
                    finish[j, i] = True
        return tokens, clens, finish

    def _promote_ready(self):
        """Activate READY slots only when every active slot sits at its
        refresh boundary (``phase % w == 0``) — or the batch is empty.
        Newly activated slots start at phase 0, so inductively ALL
        active slots share one phase residue mod the share window: the
        ``select`` decode variant dispatches on ~1/w of decode steps
        instead of nearly every step under staggered phases (the PR-5
        select-dispatch regression; ROADMAP). A slot's own schedule
        still depends only on its own phase — no global clock enters any
        slot's trajectory, so token traces are unchanged; admission is
        merely delayed by at most w-1 steps."""
        b = self.batch
        if not b.ready.any():
            return
        act = b.active
        # Speculative mode: verify steps advance each slot's phase by a
        # VARIABLE accepted count, so active phases de-align permanently
        # and the alignment precondition below could never fire again —
        # READY slots would deadlock. Promote immediately instead; a
        # slot's refresh schedule is a function of its own phase alone
        # either way, so per-slot traces are unchanged.
        if (self.spec_tokens is None and act.any()
                and (b.phase[act] % self.share_window).any()):
            return
        b.active |= b.ready
        b.ready[:] = False

    def step(self):
        """One engine step (non-blocking): feed a prompt chunk to the
        prefilling slots AND run one batched ragged decode over the
        decoding slots — the mixed prefill+decode step. A slot whose
        prompt completes this step emits its first token from the chunk
        logits and starts decoding next step."""
        b = self.batch
        self._promote_ready()
        self._prev_engine_steps = self.stats.engine_steps
        # fused decode-window routing (PR 10): strictly between two
        # selection boundaries every decoding slot runs reuse steps
        # only, so the stretch to the next boundary collapses into ONE
        # dispatched scan. Boundary steps (any slot due a selection
        # refresh) and chunk-only steps stay per-step.
        if (self._fused is not None and b.active.any()
                and not (b.active
                         & (b.phase % self.share_window == 0)).any()):
            with self._mesh_ctx():
                self._window_once(b.active.copy())
            if self._cost_model is not None:
                self._maybe_rebalance()
            return
        chunk_work = (self._schedule_chunks()
                      if self.prefill_chunk is not None else None)
        active = b.active.copy()
        if chunk_work is None and not active.any():
            return
        self.stats.engine_steps += 1
        with self._mesh_ctx():
            if chunk_work is not None:
                toks, clens = chunk_work
                logits_c, b.serve = self._chunk(
                    self.params, b.serve, jnp.asarray(toks),
                    jnp.asarray(clens), jnp.asarray(clens > 0))
                self.stats.dispatches += 1
                self.stats.prefill_chunks += 1
                for slot in np.nonzero(clens)[0]:
                    slot = int(slot)
                    b.lengths[slot] += int(clens[slot])
                    b.prompt_left[slot] -= int(clens[slot])
                    if b.prompt_left[slot] == 0:
                        self._finish_prefill(slot, logits_c)
            if active.any():
                self._decode_once(active)
        if self._cost_model is not None:
            self._maybe_rebalance()

    # ------------------------------------------------------------------
    # dynamic rebalancing (sched/cost.py + sched/rebalance.py)
    # ------------------------------------------------------------------

    def _slot_views(self):
        """Cost-model snapshot of every occupied slot (host mirrors
        only — building views never syncs the device)."""
        from repro.sched.cost import SlotView
        b = self.batch
        views = []
        for i in range(b.max_batch):
            if b.prefilling[i]:
                ph = "prefill"
            elif b.ready[i]:
                ph = "ready"
            elif b.active[i]:
                ph = "decode"
            else:
                continue
            views.append(SlotView(slot=i, uid=int(b.uid[i]),
                                  ctx=int(b.lengths[i]),
                                  prompt_left=int(b.prompt_left[i]),
                                  phase=ph))
        return views

    def compute_loads(self) -> List[float]:
        """Per-bank next-step compute loads of the live slots under the
        cost model (``rebalance_banks`` contiguous slot-index blocks;
        works with any ``rebalance`` setting — the balance report uses
        it on plain engines too)."""
        from repro.sched.cost import CostModel, device_compute_loads
        cm = self._cost_model or CostModel.from_config(
            self.cfg, hot_cap=self.hot_pages,
            spec_tokens=self.spec_tokens or 0,
            chunk_budget=self.prefill_chunk or 0)
        costs = cm.slot_costs(self._slot_views(),
                              n_shards=self.plan.page_stripe_shards)
        return device_compute_loads(
            costs, n_banks=self.rebalance_banks,
            max_batch=self.batch.max_batch,
            page_stripe_shards=self.plan.page_stripe_shards)

    def _maybe_rebalance(self):
        """End-of-step rebalance check: score the live slots' next-step
        compute, plan migrations (greedy-LPT into free indices), apply
        when the plan clears the hysteresis gate. Runs only when due
        (a retirement this step, or the interval boundary) and outside
        the cooldown window."""
        due = self._rebalance_due
        # interval trigger: fire when this step CROSSED a multiple of
        # the interval. Identical to `engine_steps % interval == 0` for
        # per-step dispatch (steps advance by 1), but a fused window
        # advances engine_steps by up to w-1 at once and may jump past
        # the multiple without landing on it.
        if (self.rebalance == "interval"
                and self.stats.engine_steps // self.rebalance_interval
                > self._prev_engine_steps // self.rebalance_interval):
            due = True
        if not due:
            return
        self._rebalance_due = False
        if (self.stats.engine_steps - self._last_rebalance_step
                < self.rebalance_cooldown):
            self.stats.rebalance_skipped += 1
            return
        from repro.sched.rebalance import plan_rebalance
        b = self.batch
        views = self._slot_views()
        if len(views) < 2:
            return
        stripes = self.plan.page_stripe_shards
        costs = self._cost_model.slot_costs(views, n_shards=stripes)
        plan = plan_rebalance(
            costs, b.free_slots(), n_banks=self.rebalance_banks,
            max_batch=b.max_batch, page_stripe_shards=stripes,
            min_gain=self.rebalance_min_gain)
        self.stats.rebalance_checks += 1
        self.stats.imbalance_pre_sum += plan.imbalance_before
        self.stats.imbalance_post_sum += plan.imbalance_after
        if not plan.moves:
            self.stats.rebalance_skipped += 1
            return
        for mv in plan.moves:
            self._migrate_slot(mv.src, mv.dst)
        self._last_rebalance_step = self.stats.engine_steps
        self.stats.rebalances += 1

    def _migrate_slot(self, src: int, dst: int):
        """Move the occupant of slot index ``src`` into the FREE index
        ``dst``: one donated jit copies the serve-state rows, sampling
        lanes, and pending token feed verbatim and clears ``src`` to the
        empty sentinels; host mirrors, far-store keys, and completion
        bookkeeping re-key alongside. Cache contents move bit-exact and
        sampling keys are owned by (seed, uid) — never the slot index —
        so token traces are unchanged (tests/test_rebalance.py)."""
        b = self.batch
        assert src != dst and b.uid[src] != -1 and b.uid[dst] == -1, (
            src, dst)
        with self._mesh_ctx():
            (b.serve, self._tok, b.samp_base, b.samp_temp, b.samp_topp,
             b.samp_gen) = self._migrate(
                b.serve, self._tok, b.samp_base, b.samp_temp,
                b.samp_topp, b.samp_gen, jnp.int32(src), jnp.int32(dst))
        self.stats.dispatches += 1
        for arr, clear in ((b.active, False), (b.prefilling, False),
                           (b.ready, False), (b.lengths, 0),
                           (b.phase, 0), (b.uid, -1), (b.remaining, 0),
                           (b.prompt_left, 0)):
            arr[dst] = arr[src]
            arr[src] = clear
        if src in self._samp_host:
            self._samp_host[dst] = self._samp_host.pop(src)
        if src in self._prompts:
            self._prompts[dst] = self._prompts.pop(src)
        if self.spec_tokens is not None:
            if src in self._spec_history:
                self._spec_history[dst] = self._spec_history.pop(src)
            self._spec_emitted[dst] = self._spec_emitted[src]
            self._spec_emitted[src] = 0
        if self._tier is not None:
            t = self._tier
            t.resident[dst] = t.resident[src].copy()
            for s, p in [k for k in t.far if k[0] == src]:
                t.far[(dst, p)] = t.far.pop((s, p))
            t.reset_slot(src)
        comp = self._live.pop(src)
        comp._slot = dst
        self._live[dst] = comp
        self.stats.migrations += 1
        self.stats.migrated_tokens += int(b.lengths[dst])

    def _decode_once(self, active: np.ndarray):
        """The decode half of a step, over the captured ``active`` mask
        (slots that finished prefilling THIS step start next step)."""
        if self.spec_tokens is not None:
            return self._verify_once(active)
        b = self.batch
        step_idx = self._trace_rows
        # selection refresh: each slot's own share-window cadence (so a
        # slot's schedule is independent of the global clock, other
        # slots, and how its admission was chunked)
        need = active & (b.phase % self.share_window == 0)
        if not np.array_equal(self._act_mirror, active):
            self._act_dev = jnp.asarray(active)
            self._act_mirror = active.copy()
        act_dev = self._act_dev
        if need.any():
            need_dev = jnp.asarray(need)
            if self._tier is not None:
                logits, b.serve = self._tier_select(need, need_dev,
                                                    act_dev)
            else:
                logits, b.serve = self._dec_sel(
                    self.params, b.serve, self._tok, act_dev, need_dev)
                self.stats.dispatches += 1
            self.stats.select_steps += 1
        else:
            logits, b.serve = self._dec_reuse(
                self.params, b.serve, self._tok, act_dev)
            self.stats.dispatches += 1
            self.stats.reuse_steps += 1
        # keep non-active rows of the token feed: a slot that finished
        # prefilling THIS step already holds its first token, which this
        # dispatch (captured mask without it) must not clobber with the
        # sample of an inactive row's garbage logits (the sampler's
        # temp=0 lane IS argmax, so greedy rows stay bit-identical to
        # the pre-sampling engine)
        tok, b.samp_gen = self._sample(logits, b.samp_base, b.samp_gen,
                                       b.samp_temp, b.samp_topp, act_dev)
        self.stats.dispatches += 1
        self._tok = jnp.where(act_dev, tok, self._tok)
        self._trace.append(self._tok[None])
        self._trace_rows += 1
        self.trace_engine_steps.append(self.stats.engine_steps)
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += float(active.mean())
        for slot in np.nonzero(active)[0]:
            b.lengths[slot] += 1
            b.phase[slot] += 1
            comp = self._live[slot]
            comp._step_idx.append(step_idx)
            comp._slot_idx.append(int(slot))
            self.stats.tokens_out += 1
            b.remaining[slot] -= 1
            if b.remaining[slot] <= 0 or b.lengths[slot] >= self.capacity:
                self._retire(slot)
        if self._tier_plan is not None:
            # prefetch/spill for the NEXT share window, one window ahead
            # of the selection refresh that will consume the pages
            self._tier_refresh()

    def _window_once(self, active: np.ndarray):
        """One fused decode window: every reuse step from here to the
        next selection boundary (capped at ``decode_window``) as ONE
        dispatched scan, with sampling and budget-driven retirement
        in-graph (runtime/serve.make_fused_window_step). The host
        applies the whole window's bookkeeping afterwards from the
        budget vector alone — a slot emits EXACTLY ``budgets[i]`` tokens
        by construction, so no device readback is needed and the loop
        stays non-blocking."""
        from repro.sched import window_budgets
        b = self.batch
        # reuse steps only read pinned-resident pages (spill candidates
        # exclude the selection, sink, and local sections), so a fused
        # window can never cold-miss; any pending refresh plan was
        # already consumed by the selection step that opened this window
        assert self._tier_plan is None, "refresh plan crossed a boundary"
        w = self.share_window
        residue = int(b.phase[np.nonzero(active)[0][0]] % w)
        n_useful, budgets = window_budgets(
            active, b.remaining, b.lengths, capacity=self.capacity,
            phase_residue=residue, share_window=w,
            window=self._fused_len)
        if not np.array_equal(self._act_mirror, active):
            self._act_dev = jnp.asarray(active)
            self._act_mirror = active.copy()
        act_dev = self._act_dev
        plan = (self._plan_window_chunks(self._fused_len)
                if self._fused_mix is not None else None)
        e0 = self.stats.engine_steps
        if plan is None:
            trace_blk, b.serve, self._tok, b.samp_gen = self._fused(
                self.params, b.serve, self._tok, act_dev, b.samp_gen,
                jnp.asarray(budgets), b.samp_base, b.samp_temp,
                b.samp_topp)
        else:
            toks, clens, finish = plan
            trace_blk, b.serve, self._tok, b.samp_gen = self._fused_mix(
                self.params, b.serve, self._tok, act_dev, b.samp_gen,
                jnp.asarray(budgets), b.samp_base, b.samp_temp,
                b.samp_topp, jnp.asarray(toks), jnp.asarray(clens),
                jnp.asarray(finish))
        self.stats.dispatches += 1
        self.stats.fused_windows += 1
        max_e = int(budgets[active].max())
        chunk_iters = (int((plan[1].sum(axis=1) > 0).sum())
                       if plan is not None else 0)
        # the window consumed as many logical engine steps as its
        # longest-running half (per-step would interleave them 1:1)
        self.stats.engine_steps += max(max_e, chunk_iters)
        self.stats.fused_steps += max_e
        self.stats.decode_steps += max_e
        self.stats.reuse_steps += max_e
        self.stats.prefill_chunks += chunk_iters
        row0 = self._trace_rows
        self._trace.append(trace_blk[:max_e])
        self._trace_rows += max_e
        for j in range(max_e):
            self.trace_engine_steps.append(e0 + 1 + j)
            self.stats.occupancy_sum += float(
                (budgets > j).sum()) / b.max_batch
        # chunk bookkeeping first (disjoint slot sets): a slot whose
        # prompt completed in-scan flips to READY exactly where the
        # per-step mixed step would have flipped it
        if plan is not None:
            for j in range(self._fused_len):
                for slot in np.nonzero(clens[j])[0]:
                    slot = int(slot)
                    b.lengths[slot] += int(clens[j, slot])
                    b.prompt_left[slot] -= int(clens[j, slot])
                    if finish[j, slot]:
                        self._finish_prefill_fused(slot, trace_blk, j,
                                                   e0 + 1 + j)
        for slot in np.nonzero(active)[0]:
            slot = int(slot)
            emitted = int(budgets[slot])
            comp = self._live[slot]
            comp._step_idx.extend(range(row0, row0 + emitted))
            comp._slot_idx.extend([slot] * emitted)
            b.lengths[slot] += emitted
            # a survivor's budget is exactly n_useful (any smaller
            # budget means a stop condition fired → it retires below),
            # so live phases stay aligned at the next boundary
            b.phase[slot] += emitted
            b.remaining[slot] -= emitted
            self.stats.tokens_out += emitted
            if (b.remaining[slot] <= 0
                    or b.lengths[slot] >= self.capacity):
                self._retire(slot)

    def _verify_once(self, active: np.ndarray):
        """The speculative decode half of a step: draft k-1 tokens per
        active slot (serving/draft.py), verify all k positions in ONE
        chunked forward at the static (B, k) bucket, and emit each
        slot's accepted prefix (always >= 1 token — the first coupled
        target). Only accepted prefixes are appended (attend-before-
        append), so there is never anything to roll back. ``max_emit``
        clamps acceptance at the slot's next selection-refresh boundary
        (phase hitting 0 mod share_window), its generation budget, and
        capacity — so selection cadence stays a pure function of the
        slot's own phase and the capacity invariant holds."""
        b = self.batch
        k = self.spec_tokens
        w = self.share_window
        need = active & (b.phase % w == 0)
        if not np.array_equal(self._act_mirror, active):
            self._act_dev = jnp.asarray(active)
            self._act_mirror = active.copy()
        act_dev = self._act_dev
        drafted = self.draft.draft(self, active, k)
        if k > 1:
            tokens = jnp.concatenate(
                [self._tok[:, None],
                 jnp.asarray(drafted, jnp.int32)], axis=1)
        else:
            tokens = self._tok[:, None]
        max_emit = np.ones((b.max_batch,), np.int64)
        for slot in np.nonzero(active)[0]:
            slot = int(slot)
            r = int(b.phase[slot]) % w
            window_left = (w - r) if r else w
            max_emit[slot] = max(1, min(k, window_left,
                                        int(b.remaining[slot]),
                                        self.capacity - int(b.lengths[slot])))
        targets, n_dev, next_dev, b.samp_gen, b.serve = self._verify(
            self.params, b.serve, tokens, act_dev, jnp.asarray(need),
            b.samp_base, b.samp_gen, b.samp_temp, b.samp_topp,
            jnp.asarray(max_emit, jnp.int32))
        self.stats.dispatches += 1
        self._tok = jnp.where(act_dev, next_dev, self._tok)
        if need.any():
            self.stats.select_steps += 1
        else:
            self.stats.reuse_steps += 1
        # the trace gets k rows per verify step (the coupled targets);
        # a slot that accepted n of them owns rows [base, base+n)
        trace_base = self._trace_rows
        self._trace.append(targets.T)               # (k, B) block
        self._trace_rows += k
        for j in range(k):
            self.trace_engine_steps.append(self.stats.engine_steps)
        self.stats.decode_steps += 1
        self.stats.spec_steps += 1
        self.stats.occupancy_sum += float(active.mean())
        # the one host sync speculation adds: accepted counts (and the
        # target tokens, for host-side draft history) per verify step
        n_host, targets_host = jax.device_get((n_dev, targets))
        need_hist = self.draft.needs_host_tokens
        for slot in np.nonzero(active)[0]:
            slot = int(slot)
            nb = int(n_host[slot])
            comp = self._live[slot]
            comp._step_idx.extend(range(trace_base, trace_base + nb))
            comp._slot_idx.extend([slot] * nb)
            b.lengths[slot] += nb
            b.phase[slot] += nb
            b.remaining[slot] -= nb
            self._spec_emitted[slot] += nb
            self.stats.tokens_out += nb
            self.stats.spec_slot_steps += 1
            self.stats.spec_drafted += k - 1
            self.stats.spec_accepted += nb
            if need_hist:
                self._spec_history[slot].extend(
                    int(t) for t in targets_host[slot, :nb])
            if b.remaining[slot] <= 0 or b.lengths[slot] >= self.capacity:
                self._retire(slot)

    def finalize(self):
        """Materialize completion tokens from the device-side trace.
        Idempotent; the only device sync in the serving loop."""
        if self._trace:
            trace = np.asarray(jnp.concatenate(self._trace))  # (T, B)
        else:
            trace = np.zeros((0, self.batch.max_batch), np.int32)
        for comp in list(self.completions.values()) + list(
                self._live.values()):
            if comp.tokens or comp._first_tok is None:
                continue  # already materialized / still prefilling
            toks = [int(np.asarray(comp._first_tok))]
            # rows are read at the slot each was EMITTED in — a later
            # migration of the slot never invalidates earlier rows
            toks.extend(int(trace[t, s]) for t, s in
                        zip(comp._step_idx, comp._slot_idx))
            comp.tokens = toks

    def busy(self) -> bool:
        """True while any work is pending: queued requests, prefilling
        slots, ready slots, or decoding slots."""
        return (bool(self._queue) or bool(self.batch.active.any())
                or bool(self.batch.prefilling.any())
                or bool(self.batch.ready.any()))

    def poll(self) -> bool:
        """Admit whatever fits, then run one engine step — the unit of
        the ``run()`` drain loop, public so external drivers (arrival
        simulators, latency harnesses) need not reach into the
        internals. Returns True if the step dispatched any work."""
        before = self.stats.engine_steps
        self._admit()
        self.step()
        return self.stats.engine_steps > before

    def sync(self):
        """Block until the device has caught up with the dispatched
        steps (latency harnesses call this per step for honest
        timestamps; the throughput path never does)."""
        jax.block_until_ready(self._tok)

    def token_engine_steps(self, comp: Completion) -> List[int]:
        """Engine-step index at which each of ``comp``'s post-first
        tokens was emitted (pairs with ``Completion.first_token_step``
        for per-token latency accounting)."""
        return [self.trace_engine_steps[r] for r in comp._step_idx]

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> Dict[int, Completion]:
        """Drain: admit + step until queue and slots are empty.

        Returns a snapshot of the completions map: a later ``run()`` on
        the same engine that reuses a uid replaces the entry in
        ``self.completions`` but never mutates an earlier run's returned
        dict (its Completion tokens are already materialized here)."""
        for r in requests or ():
            self.submit(r)
        t0 = time.time()
        while self.busy():
            self.poll()
        jax.block_until_ready(self.batch.serve["length"])
        self.stats.wall_s += time.time() - t0
        self.finalize()
        return dict(self.completions)

    def reset_metrics(self):
        """Zero stats/completions/trace between a warmup and a measured
        phase. Only legal when idle (no queued or in-flight requests)."""
        assert not self._queue and not self._live, (
            "reset_metrics() requires an idle engine")
        self.finalize()           # materialize anything still deferred
        self._trace.clear()
        self._trace_rows = 0
        self._prev_engine_steps = 0
        self.trace_engine_steps.clear()
        self.completions = {}
        self.stats = EngineStats()
        # the cooldown window is measured in engine_steps, which just
        # restarted from 0 — an un-reset watermark would block every
        # rebalance of the measured phase behind a negative delta
        self._last_rebalance_step = -(1 << 30)
        self._rebalance_due = False

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def context_lengths(self) -> np.ndarray:
        """Per-slot context lengths of live slots (for sched/balance)."""
        return self.batch.lengths[self.batch.active].copy()

    def jit_cache_sizes(self) -> Dict[str, int]:
        sizes = {
            "prefill": jit_cache_size(self._prefill),
            "decode_select": jit_cache_size(self._dec_sel),
            "decode_reuse": jit_cache_size(self._dec_reuse),
            "pack": jit_cache_size(self._pack),
        }
        if self.prefill_chunk is not None:
            sizes["prefill_chunk"] = jit_cache_size(self._chunk)
            sizes["reset"] = jit_cache_size(self._reset)
        if self.hot_pages is not None:
            sizes["tier_gather"] = jit_cache_size(self._tier_gather)
            sizes["tier_spill"] = jit_cache_size(self._tier_spill)
            sizes["tier_fill"] = jit_cache_size(self._tier_fill)
        sizes["sample"] = jit_cache_size(self._sample)
        sizes["sample_one"] = jit_cache_size(self._sample_one)
        if self._fused is not None:
            sizes["fused_window"] = jit_cache_size(self._fused)
        if self._fused_mix is not None:
            sizes["fused_window_mixed"] = jit_cache_size(self._fused_mix)
        if self.rebalance != "off":
            sizes["migrate"] = jit_cache_size(self._migrate)
        if self.spec_tokens is not None:
            sizes["verify"] = jit_cache_size(self._verify)
            for name, n in self.draft.jit_cache_sizes().items():
                sizes[f"draft_{name}"] = n
        return sizes
