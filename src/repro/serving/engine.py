"""Slot-based continuous batching over the compiled H²EAL step triple.

The lockstep loop in ``launch/serve.py`` forces every request in a batch
to share one prompt length and one generation length — exactly the
workload imbalance the paper's load-balancing scheduler (§IV-C) targets
at the bank level, replayed at the batch level. This engine removes the
lockstep:

  * ``BatchState`` holds a **fixed max-batch** compiled decode shape:
    per-slot caches, a per-slot ``length`` (B,) vector threaded through
    cache appends / attention validity (core/cache.py,
    core/hybrid_attention.py), a per-slot ``active`` mask, and a per-slot
    share-window ``phase``.
  * Admission = **prefill-then-pack**: an incoming request is prefilled
    at batch 1 (compiled once per prompt bucket), then its serve state is
    packed into a free slot of the batched state with a single donated
    ``dynamic_update_slice`` tree op — a dynamic slot index, so admission
    never recompiles.
  * Retirement flips ``active`` off; the slot's caches stay bit-stable
    (appends are masked) until the next admission overwrites them.
  * Page selection refreshes on the shared share-window clock (global
    step % w == 0, the paper's LServe-style shared selection) plus once
    at each slot's first decode step (phase == 0), and the ``select``
    variant applies the fresh selection **only** to slots whose refresh
    is due (``need_select`` blending). A slot's refresh schedule is
    therefore a function of its own admission step and the global clock
    alone — its decode logits are invariant to other slots joining or
    leaving (the co-placement exactness argument applied to continuous
    batching; tested in tests/test_serving.py).
  * The decode loop never blocks on the device: retirement is
    budget-driven, so generated tokens are left on device (one (B,)
    vector per step) and extracted once at the end of ``run()``
    (``finalize()``). The host loop dispatches steps back-to-back just
    like the lockstep driver.

After warmup (one prefill compile per prompt bucket + the two decode
variants + pack), the steady state runs with zero recompiles regardless
of how requests arrive — verified via jit cache-miss counts in
benchmarks/serve_throughput.py.

The engine runs under ANY layout registered in core/layouts.py
(AttentionLayout registry): the layout's ``plan()`` resolves and
validates the mesh, rounds the cache capacity, and decides whether the
batched state lives in a sharded placement — all at construction time,
so every layout gets the same early validation. ``coplace_shmap``
(paper §IV-B: pages sharded over the mesh 'model' axis, each device
computing partial attention for exactly the pages it stores, merged
with a cross-device log-sum-exp combine — core/hybrid_attention.py)
and ``interleave`` (paper Fig 7b: GSPMD within-page token striping) are
the sharded entries; the per-slot length/active/need_select vectors
thread straight through either decode body, and
``admission="balanced"`` adds the paper's §IV-C load balancing at the
batch dimension: queued requests are admitted in the order that keeps
per-device page load flattest (sched/balance.py). See docs/serving.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.runtime import serve as serve_rt


@dataclasses.dataclass
class Request:
    """One generation request. ``prompt`` length must be one of the
    engine's prompt buckets (pad upstream; the padded prompt is canonical)."""

    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: List[int]            # filled by Engine.finalize()
    admitted_step: int
    finished_step: int = -1
    # device-side bookkeeping until finalize():
    _first_tok: object = None    # device scalar from the prefill logits
    _slot: int = -1
    _step_idx: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class EngineStats:
    decode_steps: int = 0
    select_steps: int = 0
    reuse_steps: int = 0
    prefills: int = 0
    tokens_out: int = 0
    occupancy_sum: float = 0.0   # sum over steps of live-slot fraction
    wall_s: float = 0.0          # set by run()
    admission_reorders: int = 0  # balanced admission: non-FIFO picks

    @property
    def occupancy(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0


@dataclasses.dataclass
class BatchState:
    """Host view of the batched serve state.

    ``serve`` is the device pytree (per-slot caches + (B,) length);
    the numpy arrays mirror per-slot scheduling metadata the host loop
    needs without device round-trips.
    """

    serve: dict                  # model serve state, length: (B,) int32
    active: np.ndarray           # (B,) bool
    lengths: np.ndarray          # (B,) int64 — host mirror of serve length
    phase: np.ndarray            # (B,) int64 — decode steps since admission
    uid: np.ndarray              # (B,) int64 — -1 when free
    remaining: np.ndarray        # (B,) int64 — generation budget left

    @property
    def max_batch(self) -> int:
        return self.active.shape[0]

    def free_slots(self) -> List[int]:
        return [i for i in range(self.max_batch) if not self.active[i]]


def jit_cache_size(fn) -> int:
    """Number of compiled entries behind a jax.jit function (recompile
    counter for the no-recompiles-after-warmup check); -1 if unknown."""
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


def _pack_slot(big: dict, small: dict, slot):
    """Write the batch-1 serve state ``small`` into slot ``slot`` of the
    batched state ``big``. Slot index is dynamic — one compile total.

    Leaf batch axis: 1 for scan-stacked "blocks" leaves, else 0;
    "length" is scalar in ``small`` and (B,) in ``big``.
    """
    def upd(path, bg, sm):
        ps = jax.tree_util.keystr(path)
        if ps.endswith("['length']"):
            return jax.lax.dynamic_update_slice(
                bg, jnp.reshape(sm, (1,)).astype(bg.dtype), (slot,))
        axis = 1 if "['blocks']" in ps else 0
        start = (0,) * axis + (slot,) + (0,) * (bg.ndim - axis - 1)
        return jax.lax.dynamic_update_slice(bg, sm.astype(bg.dtype), start)

    return jax.tree_util.tree_map_with_path(upd, big, small)


class Engine:
    """Continuous-batching engine. See module docstring.

    Parameters
    ----------
    cfg, params : model config + parameters.
    max_batch   : number of slots (the compiled decode batch).
    capacity    : max context tokens any slot may reach (cache size).
    prompt_buckets : allowed prompt lengths; one prefill compile each.
    impl        : attention kernel implementation, ``"ref"`` (pure-jnp
                  oracle) or ``"pallas"`` (Pallas kernels; interpret mode
                  off-TPU). Validated and BAKED INTO the compiled step
                  functions here at construction — impl switching never
                  happens per step, so the zero-recompile invariant is
                  unaffected (docs/serving.md). Exposed as ``--attn-impl``
                  by launch/serve.py and benchmarks/serve_throughput.py.
    layout      : serve-cache layout name, resolved through the
                  core/layouts registry (unknown names raise listing the
                  registered layouts). ``None`` is a deprecated alias for
                  ``"default"``. The layout's ``plan()`` runs here at
                  construction: it resolves/validates the mesh, rounds
                  the cache capacity to the layout's quantum, and decides
                  whether the batched state is device_put into a sharded
                  placement — so a layout whose mesh requirements aren't
                  met fails NOW, not at the first decode step.
    mesh        : mesh override for sharded layouts (each layout builds
                  its own host-local default). Every jitted call runs
                  inside this mesh's context so shard_map / GSPMD paths
                  can see it.
    admission   : ``"fifo"`` (default) or ``"balanced"`` — balanced looks
                  at the first ``admit_lookahead`` queued requests and
                  admits the one that keeps per-device page load most
                  balanced (sched/balance.admission_score; the paper's
                  §IV-C balancing applied to the batch dimension).
    """

    def __init__(self, cfg: ArchConfig, params, *, max_batch: int,
                 capacity: int, prompt_buckets: Sequence[int],
                 impl: str = "ref", layout: Optional[str] = None,
                 mesh=None, admission: str = "fifo",
                 admit_lookahead: int = 4,
                 balance_shards: Optional[int] = None):
        from repro.core import layouts as layoutlib
        from repro.kernels.ops import resolve_impl

        self.cfg = cfg
        self.params = params
        self.attn_impl = resolve_impl(impl)   # raises on unknown impls
        self.layout = layoutlib.resolve_layout(layout)  # raises on unknown
        # construction-time layout planning: mesh resolution/validation,
        # capacity rounding, sharded-state requirements — every layout
        # (not just coplace_shmap) gets the same early validation
        self.plan = layoutlib.get_layout(self.layout).plan(cfg, mesh)
        self.mesh = self.plan.mesh
        assert admission in ("fifo", "balanced"), admission
        self.admission = admission
        self.admit_lookahead = max(int(admit_lookahead), 1)
        # shard count the balanced admission scores against; defaults to
        # the layout plan's (1 → FIFO). Override for an engine whose
        # pages are sharded externally (or in tests).
        self.balance_shards = balance_shards
        self.capacity = int(capacity)
        # the sharded cache needs a whole number of pages per device; the
        # retirement boundary stays at the caller's `capacity`
        self.cache_capacity = self.plan.round_capacity(self.capacity)
        self.prompt_buckets = tuple(sorted(int(b) for b in prompt_buckets))
        assert self.prompt_buckets, "need at least one prompt bucket"
        assert self.prompt_buckets[-1] < self.capacity, (
            f"largest prompt bucket {self.prompt_buckets[-1]} must leave "
            f"room to decode within capacity {self.capacity}")
        self.share_window = max(cfg.h2eal.share_window, 1)
        scfg = serve_rt.ServeConfig(capacity=self.cache_capacity,
                                    layout=self.layout, impl=self.attn_impl)
        self._prefill = jax.jit(serve_rt.make_prefill(cfg, scfg))
        self.batch = self._init_batch_state(max_batch)
        # Under a sharded layout the batched state must live in ONE stable
        # sharded placement from step 0: otherwise the first decode
        # reshards it (unsharded zeros in, sharded layout out) and
        # pack/decode each compile a second entry AFTER warmup. Pinning
        # out_shardings keeps every steady-state call on a single
        # compiled program.
        dec_shard = {}
        if self.plan.shard_state:
            from jax.sharding import NamedSharding, PartitionSpec
            ss = self.plan.state_shardings(cfg, self.batch.serve,
                                           batch_size=max_batch)
            rep = NamedSharding(self.mesh, PartitionSpec())
            self.batch.serve = jax.device_put(self.batch.serve, ss)
            dec_shard = {"out_shardings": (rep, ss)}
            self._pack = jax.jit(_pack_slot, donate_argnums=(0,),
                                 out_shardings=ss)
        else:
            self._pack = jax.jit(_pack_slot, donate_argnums=(0,))
        self._dec_sel = jax.jit(
            serve_rt.make_ragged_decode_step(cfg, scfg, do_select=True),
            donate_argnums=(1,), **dec_shard)
        self._dec_reuse = jax.jit(
            serve_rt.make_ragged_decode_step(cfg, scfg, do_select=False),
            donate_argnums=(1,), **dec_shard)
        self._tok = jnp.zeros((max_batch,), jnp.int32)   # next-token feed
        self._act_dev = jnp.zeros((max_batch,), bool)    # device active mask
        self._act_dirty = False
        self._trace: List[jax.Array] = []                # (B,) per step
        self._queue: deque[Request] = deque()
        self._live: Dict[int, Completion] = {}       # slot -> in-flight
        self.completions: Dict[int, Completion] = {}  # uid -> finished
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    # state construction
    # ------------------------------------------------------------------

    def _mesh_ctx(self):
        """Ambient-mesh context for jitted calls: the shard_map co-placement
        path discovers the mesh at trace time (runtime/hints.current_mesh),
        so every prefill/decode/pack dispatch runs inside it."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _init_batch_state(self, max_batch: int) -> BatchState:
        """All-free batched state. Cache contents are irrelevant until a
        slot is admitted (pack overwrites every leaf row), so zeros are
        fine — validity masks keep the math NaN-free."""
        cfg = self.cfg
        if cfg.embed_frontend_stub:
            probe = jax.ShapeDtypeStruct(
                (max_batch, self.prompt_buckets[0], cfg.d_model), jnp.float32)
        else:
            probe = jax.ShapeDtypeStruct(
                (max_batch, self.prompt_buckets[0]), jnp.int32)
        shapes = jax.eval_shape(
            lambda p, b: M.prefill(cfg, p, b, capacity=self.cache_capacity),
            self.params, probe)[1]
        serve = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        serve["length"] = jnp.zeros((max_batch,), jnp.int32)
        return BatchState(
            serve=serve,
            active=np.zeros((max_batch,), bool),
            lengths=np.zeros((max_batch,), np.int64),
            phase=np.zeros((max_batch,), np.int64),
            uid=np.full((max_batch,), -1, np.int64),
            remaining=np.zeros((max_batch,), np.int64),
        )

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) not in self.prompt_buckets:
            raise ValueError(
                f"prompt length {len(req.prompt)} not in buckets "
                f"{self.prompt_buckets}; pad upstream")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new} "
                             f"(every admitted request emits at least the "
                             f"prefill token)")
        self._queue.append(req)

    def _admit_one(self, req: Request, slot: int):
        prompt = jnp.asarray(np.asarray(req.prompt)[None])  # (1, S)
        with self._mesh_ctx():
            logits, small = self._prefill(self.params, prompt)
            self.stats.prefills += 1
            self.batch.serve = self._pack(self.batch.serve, small,
                                          jnp.int32(slot))
        first = jnp.argmax(logits[0], axis=-1).astype(jnp.int32)
        self._tok = self._tok.at[slot].set(first)
        b = self.batch
        b.active[slot] = True
        self._act_dirty = True
        b.lengths[slot] = len(req.prompt)
        b.phase[slot] = 0          # select on the slot's first decode step
        b.uid[slot] = req.uid
        comp = Completion(uid=req.uid, prompt_len=len(req.prompt),
                          tokens=[],
                          admitted_step=self.stats.decode_steps)
        comp._first_tok = first
        comp._slot = slot
        self._live[slot] = comp
        self.stats.tokens_out += 1
        b.remaining[slot] = req.max_new - 1
        # next append writes at position lengths[slot]; valid while < capacity
        if b.remaining[slot] <= 0 or b.lengths[slot] >= self.capacity:
            self._retire(slot)

    def _retire(self, slot: int):
        b = self.batch
        b.active[slot] = False
        self._act_dirty = True
        b.uid[slot] = -1
        b.remaining[slot] = 0
        comp = self._live.pop(slot)
        comp.finished_step = self.stats.decode_steps
        self.completions[comp.uid] = comp

    def _pick_request(self) -> Request:
        """Next request to admit. FIFO by default; ``balanced`` scores the
        first ``admit_lookahead`` queued requests with the per-device
        page-load imbalance they would create next to the live slots
        (sched/balance.admission_score) and admits the best, FIFO on ties.
        """
        n_shards = self.balance_shards or self.plan.balance_shards
        if (self.admission != "balanced" or n_shards <= 1
                or len(self._queue) <= 1):
            return self._queue.popleft()
        from repro.sched import balance
        live = [int(c) for c in self.batch.lengths[self.batch.active]]
        best_i, best_s = 0, None
        for i in range(min(self.admit_lookahead, len(self._queue))):
            s = balance.admission_score(
                live, len(self._queue[i].prompt), n_shards=n_shards,
                page_size=self.cfg.h2eal.page_size)
            if best_s is None or s < best_s - 1e-12:
                best_i, best_s = i, s
        if best_i == 0:
            return self._queue.popleft()
        self.stats.admission_reorders += 1
        req = self._queue[best_i]
        del self._queue[best_i]
        return req

    def _admit(self):
        for slot in self.batch.free_slots():
            if not self._queue:
                break
            self._admit_one(self._pick_request(), slot)

    # ------------------------------------------------------------------
    # decode loop
    # ------------------------------------------------------------------

    def step(self):
        """One batched decode step over the live slots (non-blocking)."""
        b = self.batch
        active = b.active.copy()
        if not active.any():
            return
        step_idx = self.stats.decode_steps
        # selection refresh: shared clock + each slot's first decode step
        need = active & ((b.phase == 0)
                         | (step_idx % self.share_window == 0))
        if self._act_dirty:
            self._act_dev = jnp.asarray(active)
            self._act_dirty = False
        act_dev = self._act_dev
        with self._mesh_ctx():
            if need.any():
                logits, b.serve = self._dec_sel(
                    self.params, b.serve, self._tok, act_dev,
                    jnp.asarray(need))
                self.stats.select_steps += 1
            else:
                logits, b.serve = self._dec_reuse(
                    self.params, b.serve, self._tok, act_dev)
                self.stats.reuse_steps += 1
        self._tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._trace.append(self._tok)
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += float(active.mean())
        for slot in np.nonzero(active)[0]:
            b.lengths[slot] += 1
            b.phase[slot] += 1
            comp = self._live[slot]
            comp._step_idx.append(step_idx)
            self.stats.tokens_out += 1
            b.remaining[slot] -= 1
            if b.remaining[slot] <= 0 or b.lengths[slot] >= self.capacity:
                self._retire(slot)

    def finalize(self):
        """Materialize completion tokens from the device-side trace.
        Idempotent; the only device sync in the serving loop."""
        if self._trace:
            trace = np.asarray(jnp.stack(self._trace))      # (T, B)
        else:
            trace = np.zeros((0, self.batch.max_batch), np.int32)
        for comp in list(self.completions.values()) + list(
                self._live.values()):
            if comp.tokens:
                continue  # already materialized
            toks = [int(np.asarray(comp._first_tok))]
            toks.extend(int(trace[t, comp._slot]) for t in comp._step_idx)
            comp.tokens = toks

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> Dict[int, Completion]:
        """Drain: admit + decode until queue and slots are empty."""
        for r in requests or ():
            self.submit(r)
        t0 = time.time()
        while self._queue or self.batch.active.any():
            self._admit()
            self.step()
        jax.block_until_ready(self.batch.serve["length"])
        self.stats.wall_s += time.time() - t0
        self.finalize()
        return self.completions

    def reset_metrics(self):
        """Zero stats/completions/trace between a warmup and a measured
        phase. Only legal when idle (no queued or in-flight requests)."""
        assert not self._queue and not self._live, (
            "reset_metrics() requires an idle engine")
        self.finalize()           # materialize anything still deferred
        self._trace.clear()
        self.completions = {}
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def context_lengths(self) -> np.ndarray:
        """Per-slot context lengths of live slots (for sched/balance)."""
        return self.batch.lengths[self.batch.active].copy()

    def jit_cache_sizes(self) -> Dict[str, int]:
        return {
            "prefill": jit_cache_size(self._prefill),
            "decode_select": jit_cache_size(self._dec_sel),
            "decode_reuse": jit_cache_size(self._dec_reuse),
            "pack": jit_cache_size(self._pack),
        }
