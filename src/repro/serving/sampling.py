"""Per-request stochastic sampling (temperature / top-p) for the engine.

RNG ownership is the whole design: every sampled token's PRNG key is a
pure function of ``(seed, Request.uid, generation index)`` —

    base_g  = fold_in(PRNGKey(seed), uid)        # once per request
    key_i   = fold_in(base_g, i)                 # i-th emitted token

— never of the slot the request happens to occupy or the step the
engine happens to dispatch. Token traces are therefore invariant to
slot churn, admission order, and (with the coupled verify sampler in
``runtime/serve.make_verify_step``) to ``Engine(spec_tokens=k)``:
speculative and non-speculative runs consume the SAME key stream at the
same generation indices, so they draw the same tokens
(tests/test_sampling.py).

Greedy decoding is the ``temperature == 0`` special case of the one
compiled sampler (an in-graph ``where`` over the argmax lane — no
per-request recompile, preserving the zero-post-warmup-recompile
invariant). Keys are the legacy raw ``(2,)`` uint32 threefry keys —
they vmap over the batch lane and fold_in composes in-graph.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

GREEDY_TEMP = 0.0      # temperature value meaning argmax
_MIN_TEMP = 1e-6       # divisor guard for the (dead) stochastic lane


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling policy. Defaults reproduce greedy argmax."""

    temperature: float = GREEDY_TEMP
    top_p: float = 1.0
    seed: int = 0

    def validate(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        return self


def request_key(seed: int, uid: int) -> Array:
    """Base key of a request: fold_in(PRNGKey(seed), uid). Computed once
    at admission (eagerly); per-token keys are derived in-graph."""
    return jax.random.fold_in(jax.random.PRNGKey(int(seed)), int(uid))


def token_key(base: Array, gen_idx) -> Array:
    """Key of the ``gen_idx``-th emitted token (0 = the prefill token)."""
    return jax.random.fold_in(base, gen_idx)


def _sample_row(logits: Array, key: Array, temperature: Array,
                top_p: Array) -> Array:
    """Sample one token id from one (V,) logits row.

    temperature == 0 -> argmax (bitwise the pre-sampling greedy path).
    Otherwise: temperature-scaled log-softmax, nucleus (top-p) filter
    (smallest prefix of the probability-sorted vocab whose mass reaches
    top_p; the top token always survives), Gumbel-max draw with ``key``.
    """
    greedy = jnp.argmax(logits, axis=-1)
    t = jnp.maximum(temperature, _MIN_TEMP)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32) / t, axis=-1)
    probs = jnp.exp(logp)
    order = jnp.argsort(-probs)                      # descending prob
    sorted_p = jnp.take(probs, order)
    cum_before = jnp.cumsum(sorted_p) - sorted_p     # mass BEFORE each rank
    keep_sorted = cum_before < top_p                 # rank 0 always kept
    keep = jnp.zeros(logits.shape, bool).at[order].set(keep_sorted)
    filtered = jnp.where(keep, logp, -jnp.inf)
    g = jax.random.gumbel(key, logits.shape, dtype=jnp.float32)
    stoch = jnp.argmax(filtered + g, axis=-1)
    return jnp.where(temperature <= GREEDY_TEMP, greedy,
                     stoch).astype(jnp.int32)


def sample_tokens(logits: Array, base: Array, gen_idx: Array,
                  temperature: Array, top_p: Array) -> Array:
    """Batched sampler: logits (B, V), base keys (B, 2) uint32, gen_idx
    (B,) int32, temperature/top_p (B,) -> token ids (B,) int32.

    Key derivation happens in-graph (``fold_in(base_b, gen_b)``) so one
    compiled program serves every step; the inputs that change per step
    are plain (B,) vectors.
    """
    keys = jax.vmap(token_key)(base, gen_idx)
    return jax.vmap(_sample_row)(logits, keys, temperature, top_p)


def sample_chunk(logits: Array, base: Array, gen_idx: Array,
                 temperature: Array, top_p: Array) -> Array:
    """Verify-chunk sampler: logits (B, k, V) -> tokens (B, k) int32.

    Row j of slot b is the target for generation index ``gen_b + j`` and
    uses key ``fold_in(base_b, gen_b + j)`` — EXACTLY the key the
    non-speculative sampler would use for that token, which is what makes
    rejection sampling against these coupled targets lossless samplewise,
    not just in distribution (docs/serving.md §Sampling).
    """
    k = logits.shape[1]
    offs = jnp.arange(k, dtype=gen_idx.dtype)

    def per_slot(row_logits, b_key, g0, t, p):
        keys = jax.vmap(token_key, in_axes=(None, 0))(b_key, g0 + offs)
        return jax.vmap(_sample_row, in_axes=(0, 0, None, None))(
            row_logits, keys, t, p)

    return jax.vmap(per_slot)(logits, base, gen_idx, temperature, top_p)
