"""Continuous-batching serving engine on top of the H²EAL step triple."""
from repro.serving.engine import (  # noqa: F401
    BatchState,
    Completion,
    Engine,
    EngineStats,
    Request,
    jit_cache_size,
)
