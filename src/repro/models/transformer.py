"""Decoder stack with period-scan.

Architectures repeat a short *period* of block types (dense: 1; gemma3:
5 local + 1 global; zamba2: 5 mamba2 + 1 attention; xlstm: 2 mlstm +
1 slstm). Parameters are stacked per period position with a leading
``num_periods`` axis and the stack is driven by ``lax.scan`` — compact HLO
at any depth (kimi-k2's 61 layers lower as one scanned period), which is
what makes 40-cell × 512-device dry-runs compile in reasonable time.
Remainder layers (depth % period) run unrolled after the scan.

Block modes:
  train   — full/windowed attention (optionally α-gated for head
            identification), differentiable.
  prefill — hybrid sparse attention; emits the layer's serve caches.
  decode  — one token against the serve caches.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ArchConfig,
    MIXER_ATTENTION,
    MIXER_MAMBA2,
    MIXER_MLSTM,
    MIXER_SLSTM,
)
from repro.core import cache as cachelib
from repro.core import gating as gatinglib
from repro.core import hybrid_attention as hattn
from repro.core import layouts as layoutlib
from repro.models import moe as moelib
from repro.models import ssm as ssmlib
from repro.models import xlstm as xlstmlib
from repro.models.layers import (
    apply_rope,
    dense,
    init_dense,
    rms_norm,
    rope_cos_sin,
    swiglu,
)

Array = jax.Array


def period_len(cfg: ArchConfig) -> int:
    if cfg.mixer_pattern:
        return len(cfg.mixer_pattern)
    if cfg.attn_pattern == "local_global":
        return cfg.local_global_ratio + 1
    return 1


def layer_layout(cfg: ArchConfig) -> tuple[int, int]:
    """(num_periods, num_remainder_layers)."""
    p = period_len(cfg)
    return cfg.num_layers // p, cfg.num_layers % p


def attn_spec(cfg: ArchConfig, pos: int, impl: str) -> hattn.AttnSpec:
    """AttnSpec for period position ``pos`` (layer i ≡ pos mod period)."""
    window = 0
    if cfg.attn_pattern == "local_global" and not cfg.layer_is_global_attn(pos):
        window = cfg.local_window
    return hattn.AttnSpec(
        n_q=cfg.num_heads,
        n_kv=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        h2=cfg.h2eal,
        window=window,
        impl=impl,
    )


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn_block(key, cfg: ArchConfig, pos: int, dtype):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "ln1": jnp.zeros((d,), dtype),
        "wq": init_dense(ks[0], d, cfg.num_heads * hd, dtype=dtype),
        "wk": init_dense(ks[1], d, cfg.num_kv_heads * hd, dtype=dtype),
        "wv": init_dense(ks[2], d, cfg.num_kv_heads * hd, dtype=dtype),
        "wo": init_dense(ks[3], cfg.num_heads * hd, d, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.layer_has_ffn(pos):
        p["ln2"] = jnp.zeros((d,), dtype)
        if cfg.moe.enabled:
            p["moe"] = moelib.init_moe(ks[4], cfg, dtype=dtype)
        else:
            p["ffn"] = {
                "w_gate": init_dense(ks[5], d, cfg.d_ff, dtype=dtype),
                "w_up": init_dense(ks[6], d, cfg.d_ff, dtype=dtype),
                "w_down": init_dense(ks[7], cfg.d_ff, d, dtype=dtype),
            }
    return p


def _init_block(key, cfg: ArchConfig, pos: int, dtype):
    mixer = cfg.mixer_for_layer(pos)
    if mixer == MIXER_ATTENTION:
        return _init_attn_block(key, cfg, pos, dtype)
    ks = jax.random.split(key, 2)
    if mixer == MIXER_MAMBA2:
        p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
             "mamba": ssmlib.init_mamba2(ks[0], cfg, dtype=dtype)}
    elif mixer == MIXER_MLSTM:
        p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
             "xl": xlstmlib.init_mlstm(ks[0], cfg, dtype=dtype)}
    elif mixer == MIXER_SLSTM:
        p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
             "xl": xlstmlib.init_slstm(ks[0], cfg, dtype=dtype)}
    else:
        raise ValueError(mixer)
    if cfg.layer_has_ffn(pos):
        kf = jax.random.split(ks[1], 3)
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if cfg.moe.enabled:
            p["moe"] = moelib.init_moe(kf[0], cfg, dtype=dtype)
        else:
            p["ffn"] = {
                "w_gate": init_dense(kf[0], cfg.d_model, cfg.d_ff, dtype=dtype),
                "w_up": init_dense(kf[1], cfg.d_model, cfg.d_ff, dtype=dtype),
                "w_down": init_dense(kf[2], cfg.d_ff, cfg.d_model, dtype=dtype),
            }
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    n_per, n_rem = layer_layout(cfg)
    p_len = period_len(cfg)
    keys = jax.random.split(key, 3)

    params: dict[str, Any] = {}
    if not cfg.embed_frontend_stub:
        from repro.models.layers import init_embed
        params["embed"] = init_embed(keys[0], cfg.vocab_size, cfg.d_model,
                                     dtype=dtype)
    blocks = {}
    bkeys = jax.random.split(keys[1], p_len)
    for pos in range(p_len):
        if n_per > 0:
            stacked = [
                _init_block(jax.random.fold_in(bkeys[pos], per), cfg, pos, dtype)
                for per in range(n_per)
            ]
            blocks[f"pos{pos}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *stacked)
    params["blocks"] = blocks
    rem = {}
    for r in range(n_rem):
        pos = r  # remainder layers continue the pattern
        rem[f"rem{r}"] = _init_block(
            jax.random.fold_in(keys[1], 10_000 + r), cfg, pos, dtype)
    params["rem"] = rem
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[2], cfg.d_model, cfg.vocab_size,
                                       dtype=dtype)
    return params


def default_plan(cfg: ArchConfig):
    """Per-layer kv-head permutation (retrieval heads first).

    The real permutation comes from gating (core/gating.py) + the scheduler
    (sched/tiling.py); the default is the identity on every layer.
    """
    n_per, n_rem = layer_layout(cfg)
    p_len = period_len(cfg)
    perm = jnp.arange(cfg.num_kv_heads, dtype=jnp.int32)
    plan = {"blocks": {}, "rem": {}}
    for pos in range(p_len):
        if n_per > 0:
            plan["blocks"][f"pos{pos}"] = {
                "perm": jnp.broadcast_to(perm, (n_per, cfg.num_kv_heads))}
    for r in range(n_rem):
        plan["rem"][f"rem{r}"] = {"perm": perm}
    return plan


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _ffn_apply(cfg: ArchConfig, p, x):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        return x + moelib.moe_ffn(cfg, p["moe"], h)
    f = p["ffn"]
    return x + swiglu(h, f["w_gate"], f["w_up"], f["w_down"])


def _qkv(cfg: ArchConfig, p, h):
    hd = cfg.resolved_head_dim
    q = dense(h, p["wq"], p.get("bq"))
    k = dense(h, p["wk"], p.get("bk"))
    v = dense(h, p["wv"], p.get("bv"))
    if h.ndim == 3:  # (B, S, ·)
        b, s, _ = h.shape
        return (q.reshape(b, s, cfg.num_heads, hd),
                k.reshape(b, s, cfg.num_kv_heads, hd),
                v.reshape(b, s, cfg.num_kv_heads, hd))
    b, _ = h.shape
    return (q.reshape(b, cfg.num_heads, hd),
            k.reshape(b, cfg.num_kv_heads, hd),
            v.reshape(b, cfg.num_kv_heads, hd))


def block_train(cfg: ArchConfig, pos: int, p, plan, x, rope, *,
                impl="ref", alpha=None):
    """Training/eval forward for one block. x: (B, S, d)."""
    from repro.runtime import hints
    p = hints.unshard_block_params(p)
    x = hints.act(x)
    mixer = cfg.mixer_for_layer(pos)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == MIXER_ATTENTION:
        spec = attn_spec(cfg, pos, impl)
        q, k, v = _qkv(cfg, p, h)
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if alpha is not None:
            o = gatinglib.gated_attention(
                q, k, v, alpha, sink=cfg.h2eal.sink, local=cfg.h2eal.local,
                impl=impl)
        else:
            from repro.kernels import ops as kops
            o = kops.flash_attention(q, k, v, causal=True,
                                     window=spec.window, impl=impl)
        b, s, _, _ = o.shape
        x = x + dense(o.reshape(b, s, -1), p["wo"])
    elif mixer == MIXER_MAMBA2:
        x = x + ssmlib.mamba2_forward(cfg, p["mamba"], h)
    elif mixer == MIXER_MLSTM:
        x = x + xlstmlib.mlstm_forward(cfg, p["xl"], h)
    elif mixer == MIXER_SLSTM:
        x = x + xlstmlib.slstm_forward(cfg, p["xl"], h)
    if cfg.layer_has_ffn(pos):
        x = _ffn_apply(cfg, p, x)
    return x


def block_prefill(cfg: ArchConfig, pos: int, p, plan, x, rope, *,
                  capacity: int, impl="ref", layout=None):
    """Prefill: like train but hybrid attention + emits serve cache."""
    from repro.runtime import hints
    p = hints.unshard_block_params(p)
    x = hints.act(x)
    mixer = cfg.mixer_for_layer(pos)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    cache: Any = ()
    if mixer == MIXER_ATTENTION:
        spec = attn_spec(cfg, pos, impl)
        q, k, v = _qkv(cfg, p, h)
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        s_len = q.shape[1]
        perm = plan["perm"]
        o = hattn.prefill_attention(spec, q, k, v, perm)
        if spec.h2.enabled and spec.window == 0:
            # the layout entry decides the physical page order (e.g.
            # coplace_shmap's round-robin striping sized to the ambient
            # mesh); see core/layouts.py
            cache = layoutlib.get_layout(layout).prefill(
                spec, k, v, s_len, capacity, perm)
        else:  # full-attention baseline / plain window layer
            ctx_cap = capacity
            full = cachelib.make_full_cache(
                q.shape[0], cfg.num_kv_heads, ctx_cap, spec.head_dim,
                dtype=k.dtype)
            kk = jnp.pad(k, ((0, 0), (0, ctx_cap - s_len), (0, 0), (0, 0)))
            vv = jnp.pad(v, ((0, 0), (0, ctx_cap - s_len), (0, 0), (0, 0)))
            full = cachelib.FullCache(k=kk.transpose(0, 2, 1, 3),
                                      v=vv.transpose(0, 2, 1, 3))
            cache = {"full": full}
        b, s, _, _ = o.shape
        x = x + dense(o.reshape(b, s, -1), p["wo"])
    elif mixer == MIXER_MAMBA2:
        # run chunked forward, then recompute final state via a short scan:
        # cheaper: run the recurrence on the last chunk only is not exact;
        # we run the full recurrent scan for the state (prefill happens once)
        y, st = _mamba2_prefill_with_state(cfg, p["mamba"], h)
        x = x + y
        cache = {"ssm": st}
    elif mixer in (MIXER_MLSTM, MIXER_SLSTM):
        y, st = _xlstm_prefill_with_state(cfg, mixer, p["xl"], h)
        x = x + y
        cache = {"xl": st}
    if cfg.layer_has_ffn(pos):
        x = _ffn_apply(cfg, p, x)
    return x, cache


def block_prefill_chunk(cfg: ArchConfig, pos: int, p, plan, x, rope, cache,
                        *, start, chunk_len, active, impl="ref",
                        layout=None):
    """Chunked prefill: one prompt chunk through one block. x: (B, C, d);
    ``rope`` is (cos, sin) at each slot's chunk positions (B, C, half);
    ``cache`` is the block's serve cache being grown in place. ``start``
    (B,) is each slot's context before the chunk, ``chunk_len`` (B,) its
    valid tokens, ``active`` (B,) the slots prefilling this step. Rows
    past chunk_len / inactive slots append nothing and produce garbage
    activations (attention masks keep them out of every other position,
    recurrent mixers freeze their scan state past chunk_len; the FFN is
    pointwise). Recurrent mixers resume their per-slot saved state
    (conv history + SSM/cell state) and write the advanced state back
    into the block cache — the chunk-resumable scan that lets every
    mixer share chunked admission."""
    from repro.runtime import hints
    p = hints.unshard_block_params(p)
    x = hints.act(x)
    mixer = cfg.mixer_for_layer(pos)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    b, cch = x.shape[0], x.shape[1]
    if mixer == MIXER_ATTENTION:
        spec = attn_spec(cfg, pos, impl)
        q, k, v = _qkv(cfg, p, h)
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if spec.h2.enabled and spec.window == 0:
            inputs = layoutlib.PrefillInputs(
                q=q, k_new=k, v_new=v, start=start, chunk_len=chunk_len,
                active=active)
            o, cache = layoutlib.dispatch_prefill_chunk(
                layout, spec, cache, inputs, perm=plan["perm"])
        else:  # full-attention baseline / plain window layer
            from repro.core import paging
            full = cachelib.full_cache_append_chunk(
                cache["full"], k, v, start, chunk_len, active=active)
            pos_q = paging.chunk_positions(start, cch)
            key_pos = jnp.arange(full.k.shape[2], dtype=jnp.int32)
            kp = key_pos[None, None, None, :]
            pq = pos_q[:, None, :, None]
            valid = jnp.broadcast_to(
                kp <= pq, (b, full.k.shape[1], cch, full.k.shape[2]))
            if spec.window > 0:
                valid = valid & (kp > pq - spec.window)
            from repro.kernels import ops as kops
            o = kops.chunk_attention(q, full.k, full.v, valid,
                                     impl=spec.impl)
            cache = {"full": full}
        x = x + dense(o.reshape(b, cch, -1), p["wo"])
    elif mixer == MIXER_MAMBA2:
        y, st = ssmlib.mamba2_prefill_chunk(
            cfg, p["mamba"], cache["ssm"], h, chunk_len=chunk_len,
            active=active)
        x = x + y
        cache = {"ssm": st}
    elif mixer == MIXER_MLSTM:
        y, st = xlstmlib.mlstm_prefill_chunk(
            cfg, p["xl"], cache["xl"], h, chunk_len=chunk_len,
            active=active)
        x = x + y
        cache = {"xl": st}
    elif mixer == MIXER_SLSTM:
        y, st = xlstmlib.slstm_prefill_chunk(
            cfg, p["xl"], cache["xl"], h, chunk_len=chunk_len,
            active=active)
        x = x + y
        cache = {"xl": st}
    if cfg.layer_has_ffn(pos):
        x = _ffn_apply(cfg, p, x)
    return x, cache


def block_verify_chunk(cfg: ArchConfig, pos: int, p, plan, x, rope, cache,
                       *, start, active, need_select, impl="ref",
                       layout=None):
    """Speculative verify: k drafted tokens through one block as k decode
    steps in one chunked attention, WITHOUT touching the block's KV
    caches (selection/importance refresh only — see
    core/hybrid_attention.chunk_verify_attention). x: (B, k, d); ``rope``
    is (cos, sin) at positions start .. start+k-1. Returns
    (x, cache, (k_roped, v)) — the roped chunk KV is stashed so
    ``block_verify_append`` can commit the accepted prefix after the
    acceptance length is known, without recomputing projections.

    Speculation is gated to all-attention hybrid stacks at Engine
    construction, so unlike the other block modes there is no mixer
    branch here."""
    from repro.runtime import hints
    p = hints.unshard_block_params(p)
    x = hints.act(x)
    spec = attn_spec(cfg, pos, impl)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    b, kch = x.shape[0], x.shape[1]
    q, k, v = _qkv(cfg, p, h)
    cos, sin = rope
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    inputs = layoutlib.VerifyInputs(
        q=q, k_new=k, v_new=v, start=start, active=active,
        need_select=need_select)
    o, cache = layoutlib.dispatch_verify_chunk(
        layout, spec, cache, inputs, perm=plan["perm"])
    x = x + dense(o.reshape(b, kch, -1), p["wo"])
    if cfg.layer_has_ffn(pos):
        x = _ffn_apply(cfg, p, x)
    return x, cache, (k, v)


def block_verify_append(cfg: ArchConfig, pos: int, plan, cache, kv, *,
                        start, accepted, active, impl="ref", layout=None):
    """Commit the accepted prefix of a verified chunk into one block's
    caches from the (k_roped, v) stash of ``block_verify_chunk``.
    Returns the new block cache."""
    spec = attn_spec(cfg, pos, impl)
    k, v = kv
    inputs = layoutlib.VerifyInputs(
        q=k, k_new=k, v_new=v, start=start, active=active)
    return layoutlib.dispatch_verify_append(
        layout, spec, cache, inputs, accepted, perm=plan["perm"])


def block_decode(cfg: ArchConfig, pos: int, p, plan, x, rope1, cache, *,
                 length, do_select: bool, impl="ref", layout=None,
                 active=None, need_select=None):
    """Decode one token. x: (B, d). ``length`` is scalar (lockstep) or
    (B,) per-slot (continuous batching); ``active``/``need_select`` are the
    ragged path's per-slot masks (see core/hybrid_attention.py)."""
    from repro.runtime import hints
    p = hints.unshard_block_params(p)
    mixer = cfg.mixer_for_layer(pos)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == MIXER_ATTENTION:
        spec = attn_spec(cfg, pos, impl)
        q, k, v = _qkv(cfg, p, h)
        cos1, sin1 = rope1  # (B?, 1, half) at position `length`
        q = apply_rope(q[:, None], cos1, sin1)[:, 0]
        k = apply_rope(k[:, None], cos1, sin1)[:, 0]
        q = hints.decode_qkv(q)
        k = hints.decode_qkv(k)
        v = hints.decode_qkv(v)
        if "full" in cache:
            o, full = hattn.full_decode_attention(
                spec, q, k, v, cache["full"], length, active=active)
            cache = {"full": full}
        else:
            inputs = layoutlib.DecodeInputs(
                q=q, k_new=k, v_new=v, lengths=length, active=active,
                need_select=need_select)
            o, cache = layoutlib.dispatch_decode(
                layout, spec, cache, inputs, do_select=do_select,
                perm=plan["perm"])
        b = o.shape[0]
        x = x + dense(o.reshape(b, -1), p["wo"])
    elif mixer == MIXER_MAMBA2:
        y, st = ssmlib.mamba2_step(cfg, p["mamba"], cache["ssm"], h)
        x = x + y
        cache = {"ssm": _keep_active(st, cache["ssm"], active)}
    elif mixer == MIXER_MLSTM:
        y, st = xlstmlib.mlstm_step(cfg, p["xl"], cache["xl"], h)
        x = x + y
        cache = {"xl": _keep_active(st, cache["xl"], active)}
    elif mixer == MIXER_SLSTM:
        y, st = xlstmlib.slstm_step(cfg, p["xl"], cache["xl"], h)
        x = x + y
        cache = {"xl": _keep_active(st, cache["xl"], active)}
    if cfg.layer_has_ffn(pos):
        x = _ffn_apply(cfg, p, x)
    return x, cache


def _keep_active(new, old, active):
    """Freeze recurrent state for slots not decoding this ragged step —
    a slot mid-chunked-prefill keeps its saved chunk state intact across
    interleaved decode steps (the attention caches get the same
    protection from their append ops' ``active`` masks). ``active`` is
    None on the lockstep path: no-op."""
    if active is None:
        return new
    act = jnp.asarray(active).reshape(-1)
    keep = lambda n, o: jnp.where(
        act.reshape((act.shape[0],) + (1,) * (n.ndim - 1)), n, o)
    return jax.tree.map(keep, new, old)


def _mamba2_prefill_with_state(cfg, p, h):
    """Chunked forward + exact final SSM/conv state."""
    y = ssmlib.mamba2_forward(cfg, p, h)
    st = ssmlib.mamba2_final_state(cfg, p, h)
    return y, st


def _xlstm_prefill_with_state(cfg, mixer, p, h):
    """Run the scan and keep the final recurrent state."""
    if mixer == MIXER_MLSTM:
        b, L, d = h.shape
        nh = cfg.num_heads
        hd = d // nh
        qkv = dense(h, p["w_qkv"]).astype(jnp.float32)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        it, ft = xlstmlib._mlstm_gates(p, h)
        o = jax.nn.sigmoid(dense(h, p["w_o"]).astype(jnp.float32))

        def step(state, inp):
            qt, kt, vt, i_t, f_t = inp
            state, h_t = xlstmlib._mlstm_update(
                state, qt.reshape(b, nh, hd), kt.reshape(b, nh, hd),
                vt.reshape(b, nh, hd), i_t, f_t)
            return state, h_t

        s0 = xlstmlib.init_mlstm_state(cfg, b)
        s_fin, hs = jax.lax.scan(
            step, s0, (q.transpose(1, 0, 2), k.transpose(1, 0, 2),
                       v.transpose(1, 0, 2), it.transpose(1, 0, 2),
                       ft.transpose(1, 0, 2)))
        hs = hs.transpose(1, 0, 2, 3).reshape(b, L, d)
        y = (o * hs).astype(h.dtype)
        y = rms_norm(y, p["norm_w"], cfg.norm_eps)
        return dense(y, p["out_proj"]), s_fin
    # slstm
    b, L, d = h.shape
    wx = dense(h, p["w"])

    def step(state, wxt):
        return xlstmlib._slstm_step_inner(cfg, p, state, wxt)

    s0 = xlstmlib.init_slstm_state(cfg, b)
    s_fin, hs = jax.lax.scan(step, s0, wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, L, d).astype(h.dtype)
    y = rms_norm(hs, p["norm_w"], cfg.norm_eps)
    return dense(y, p["out_proj"]), s_fin
