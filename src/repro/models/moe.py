"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch avoids the (T, E, C) one-hot tensor of the classic GShard einsum
(prohibitive at 1M tokens × 384 experts): tokens are replicated top_k
times, sorted by expert id, given a within-expert slot by a cumulative
count, and scattered into an (E, capacity, d) buffer. Expert matmuls are
then dense (E-sharded under EP), and results are gathered back and
combined with the router weights. Tokens beyond an expert's capacity are
dropped (standard Switch-style, capacity_factor 1.25).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def np_prod(shape) -> int:
    return math.prod(shape)
from repro.models.layers import dense, init_dense, swiglu

Array = jax.Array

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    f = cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": init_dense(ks[0], d, m.num_experts, dtype=jnp.float32,
                             scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (m.num_experts, d, f)) * scale
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (m.num_experts, d, f)) * scale
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (m.num_experts, f, d)) *
                   (1.0 / jnp.sqrt(f))).astype(dtype),
    }
    if m.shared_expert_ff:
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": init_dense(kss[0], d, m.shared_expert_ff, dtype=dtype),
            "w_up": init_dense(kss[1], d, m.shared_expert_ff, dtype=dtype),
            "w_down": init_dense(kss[2], m.shared_expert_ff, d, dtype=dtype),
        }
    return p


def _capacity(tokens: int, num_experts: int, top_k: int,
              factor: float) -> int:
    if factor <= 0:  # dropless (smoke configs / exactness tests)
        return tokens * top_k
    cap = int(tokens * top_k * factor / num_experts) + 1
    return max(8, -(-cap // 8) * 8)  # 8-aligned


# prefill at 32k x 32 pushes 1M tokens through the router at once; the
# dispatch buffers are chunked over tokens to bound the live set
MOE_CHUNK_TOKENS = 65536


def moe_ffn(cfg: ArchConfig, params, x: Array) -> Array:
    """x: (B, S, d) or (B, d) -> same shape."""
    m = cfg.moe
    orig_shape = x.shape
    d = x.shape[-1]
    t = int(np_prod(x.shape[:-1]))
    if t > MOE_CHUNK_TOKENS and t % MOE_CHUNK_TOKENS == 0:
        nc = t // MOE_CHUNK_TOKENS
        xc = x.reshape(nc, MOE_CHUNK_TOKENS, d)

        def body(_, xi):
            return None, _moe_ffn_flat(cfg, params, xi)

        _, yc = jax.lax.scan(body, None, xc)
        return yc.reshape(orig_shape)
    return _moe_ffn_flat(cfg, params, x.reshape(t, d)).reshape(orig_shape)


def _moe_ffn_flat(cfg: ArchConfig, params, xf: Array) -> Array:
    """xf: (T, d) -> (T, d)."""
    m = cfg.moe
    t, d = xf.shape
    e, k = m.num_experts, m.top_k

    logits = dense(xf.astype(jnp.float32), params["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, k)                                # (T, K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    cap = _capacity(t, e, k, m.capacity_factor)
    flat_ids = ids.reshape(-1)                                      # (T*K,)
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(sorted_ids, length=e)
    starts = jnp.cumsum(counts) - counts                            # (E,)
    slots = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_ids]
    keep = slots < cap
    slots_c = jnp.minimum(slots, cap - 1)
    src_tok = order // k                                            # (T*K,)

    buf = jnp.zeros((e, cap, d), xf.dtype)
    vals = jnp.where(keep[:, None], xf[src_tok], 0.0).astype(xf.dtype)
    buf = buf.at[sorted_ids, slots_c].set(vals, mode="drop")

    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(xf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(xf.dtype))
    a = jax.nn.silu(g) * u
    y_buf = jnp.einsum("ecf,efd->ecd", a, params["w_down"].astype(xf.dtype))

    y_tok = y_buf[sorted_ids, slots_c]                              # (T*K, d)
    y_tok = jnp.where(keep[:, None], y_tok, 0.0)
    wk = w.reshape(-1)[order].astype(xf.dtype)
    out = jnp.zeros((t, d), xf.dtype).at[src_tok].add(y_tok * wk[:, None])

    if "shared" in params:
        sp = params["shared"]
        out = out + swiglu(xf, sp["w_gate"], sp["w_up"], sp["w_down"])
    return out


def aux_load_balance_loss(cfg: ArchConfig, x: Array, params) -> Array:
    """Switch-style auxiliary loss: E · Σ_e f_e · p_e."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    logits = dense(xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = jax.lax.top_k(probs, m.top_k)
    onehot = jax.nn.one_hot(ids[..., 0], m.num_experts)
    f = onehot.mean(0)
    p = probs.mean(0)
    return m.num_experts * jnp.sum(f * p)
