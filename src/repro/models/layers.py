"""Shared neural-net building blocks (pure JAX, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


def dense(x: Array, w: Array, b: Array | None = None) -> Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# RoPE (computed from positions — no precomputed table so 500k ctx is free)
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """positions: (..., S) int32 -> cos/sin (..., S, head_dim//2) f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, D); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch & heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:  # (B, S, half)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    c = c.astype(x.dtype)
    s = s.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def init_embed(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)
