"""xLSTM blocks (sLSTM + mLSTM) — arXiv:2405.04517, simplified faithfully.

Both blocks use exponential gating with the max-stabilizer state m_t, so
the recurrence is numerically exact in f32. Training/prefill run the same
recurrence under ``lax.scan`` (sLSTM is inherently sequential — its
recurrent weights R forbid a parallel form; mLSTM is kept scan-based too,
which keeps HLO compact; decode is O(1)/token for both — the property that
matters for the long-context serving shapes).

mLSTM (matrix memory, heads H, key/value dim P = d_model/H):
    C_t = f_t · C_{t-1} + i_t · (k_t v_tᵀ)      C: (P, P)
    n_t = f_t · n_{t-1} + i_t · k_t
    h_t = o_t ⊙ (C_tᵀ q_t) / max(|n_tᵀ q_t|, 1)

sLSTM (scalar memory per head-channel, recurrent gate inputs):
    c_t = f_t ⊙ c_{t-1} + i_t ⊙ z_t,  n_t = f_t ⊙ n_{t-1} + i_t
    h_t = o_t ⊙ c_t / n_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, init_dense, rms_norm

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "w_qkv": init_dense(ks[0], d, 3 * d, dtype=dtype),
        "w_if": init_dense(ks[1], d, 2 * h, dtype=dtype, scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]
                                ).astype(jnp.float32),
        "w_o": init_dense(ks[2], d, d, dtype=dtype),
        "norm_w": jnp.zeros((d,), dtype),
        "out_proj": init_dense(ks[3], d, d, dtype=dtype),
    }


def _mlstm_gates(params, x):
    """x: (..., d) -> (i_tilde, f_tilde) each (..., H) in f32."""
    g = dense(x, params["w_if"]).astype(jnp.float32) + params["b_if"]
    h = g.shape[-1] // 2
    return g[..., :h], g[..., h:]


def init_mlstm_state(cfg: ArchConfig, bsz: int):
    h = cfg.num_heads
    p = cfg.d_model // h
    return {
        "C": jnp.zeros((bsz, h, p, p), jnp.float32),
        "n": jnp.zeros((bsz, h, p), jnp.float32),
        "m": jnp.full((bsz, h), -jnp.inf, jnp.float32),
    }


def _mlstm_update(state, q, k, v, it, ft):
    """One stabilized step. q/k/v: (B,H,P) f32; it/ft: (B,H)."""
    m_new = jnp.maximum(ft + state["m"], it)
    m_prev_finite = jnp.isfinite(state["m"])
    i_p = jnp.exp(it - m_new)
    f_p = jnp.where(m_prev_finite, jnp.exp(ft + state["m"] - m_new), 0.0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    C = f_p[..., None, None] * state["C"] + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_p[..., None] * state["n"] + i_p[..., None] * k
    hq = jnp.einsum("bhpq,bhp->bhq", C, q * scale)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q * scale)), 1.0)
    h_t = hq / denom[..., None]
    return {"C": C, "n": n, "m": m_new}, h_t


def mlstm_forward(cfg: ArchConfig, params, x: Array) -> Array:
    """x: (B, L, d) -> (B, L, d) via scan over time."""
    b, L, d = x.shape
    h = cfg.num_heads
    p = d // h
    qkv = dense(x, params["w_qkv"]).astype(jnp.float32)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    it, ft = _mlstm_gates(params, x)
    o = jax.nn.sigmoid(dense(x, params["w_o"]).astype(jnp.float32))

    def step(state, inp):
        qt, kt, vt, i_t, f_t = inp
        state, h_t = _mlstm_update(
            state,
            qt.reshape(b, h, p), kt.reshape(b, h, p), vt.reshape(b, h, p),
            i_t, f_t)
        return state, h_t

    s0 = init_mlstm_state(cfg, b)
    xs = (q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2),
          it.transpose(1, 0, 2), ft.transpose(1, 0, 2))
    _, hs = jax.lax.scan(step, s0, xs)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, L, d)
    y = (o * hs).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    return dense(y, params["out_proj"])


def _masked_scan_resume(state, step_fn, xs, valid, bsz):
    """Run a recurrence over a chunk resuming from ``state``, freezing
    state leaves at positions past each slot's chunk_len.

    step_fn(state, inp) -> (state', h_t); xs: time-major per-step inputs;
    valid: (C, B) bool. Masked steps (ragged tail, inactive slots) leave
    every leaf untouched, so the resume is bit-exact vs one packed scan —
    the per-step updates are the identical float ops on identical
    operands (the -inf m stabilizer stays safe: the isfinite guards in
    the update fns run regardless, and jnp.where selects the old leaf).
    """

    def step(st, inp):
        *inner, vld = inp
        st2, h_t = step_fn(st, inner)
        keep = lambda new, old: jnp.where(
            vld.reshape((bsz,) + (1,) * (new.ndim - 1)), new, old)
        return jax.tree.map(keep, st2, st), h_t

    return jax.lax.scan(step, state, (*xs, valid))


def mlstm_prefill_chunk(cfg: ArchConfig, params, state, x: Array, *,
                        chunk_len, active=None):
    """One prefill chunk resuming from per-slot saved (C, n, m) state.

    x: (B, C, d); state: as ``init_mlstm_state``; chunk_len: scalar or
    (B,) valid tokens; active: (B,) bool. Returns (y (B, C, d), state').
    Outputs past chunk_len are garbage the caller ignores; masked steps
    are identity on the state, so chunked prefill is bit-exact vs packed
    for the recurrence itself.
    """
    b, c, d = x.shape
    h = cfg.num_heads
    p = d // h
    qkv = dense(x, params["w_qkv"]).astype(jnp.float32)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    it, ft = _mlstm_gates(params, x)
    o = jax.nn.sigmoid(dense(x, params["w_o"]).astype(jnp.float32))
    eff = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
    if active is not None:
        eff = jnp.where(jnp.asarray(active).reshape(b), eff, 0)
    valid = jnp.arange(c)[None, :] < eff[:, None]                  # (B,C)

    def step_fn(st, inner):
        qt, kt, vt, i_t, f_t = inner
        return _mlstm_update(
            st, qt.reshape(b, h, p), kt.reshape(b, h, p),
            vt.reshape(b, h, p), i_t, f_t)

    xs = (q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2),
          it.transpose(1, 0, 2), ft.transpose(1, 0, 2))
    new_state, hs = _masked_scan_resume(state, step_fn, xs, valid.T, b)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, c, d)
    y = (o * hs).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    return dense(y, params["out_proj"]), new_state


def mlstm_step(cfg: ArchConfig, params, state, x: Array):
    """x: (B, d) -> (y (B, d), state')."""
    b, d = x.shape
    h = cfg.num_heads
    p = d // h
    qkv = dense(x, params["w_qkv"]).astype(jnp.float32)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    it, ft = _mlstm_gates(params, x)
    o = jax.nn.sigmoid(dense(x, params["w_o"]).astype(jnp.float32))
    state, h_t = _mlstm_update(
        state, q.reshape(b, h, p), k.reshape(b, h, p), v.reshape(b, h, p),
        it, ft)
    y = (o * h_t.reshape(b, d)).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    return dense(y, params["out_proj"]), state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.num_heads
    p = d // h
    ks = jax.random.split(key, 4)
    return {
        "w": init_dense(ks[0], d, 4 * d, dtype=dtype),
        "r": (jax.random.normal(ks[1], (h, p, 4 * p)) / jnp.sqrt(p)
              ).astype(dtype),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm_w": jnp.zeros((d,), dtype),
        "out_proj": init_dense(ks[2], d, d, dtype=dtype),
    }


def init_slstm_state(cfg: ArchConfig, bsz: int):
    d = cfg.d_model
    h = cfg.num_heads
    p = d // h
    return {
        "c": jnp.zeros((bsz, h, p), jnp.float32),
        "n": jnp.zeros((bsz, h, p), jnp.float32),
        "m": jnp.full((bsz, h, p), -jnp.inf, jnp.float32),
        "h": jnp.zeros((bsz, h, p), jnp.float32),
    }


def _slstm_step_inner(cfg, params, state, wx):
    """wx: (B, 4d) precomputed W x_t. Returns (state', h_t (B,H,P))."""
    d = cfg.d_model
    h = cfg.num_heads
    p = d // h
    b = wx.shape[0]
    rh = jnp.einsum("bhp,hpq->bhq", state["h"], params["r"].astype(jnp.float32))
    g = wx.astype(jnp.float32).reshape(b, h, 4 * p) + rh + \
        params["b"].reshape(h, 4 * p)
    z_t, i_t, f_t, o_t = jnp.split(g, 4, axis=-1)
    z_t = jnp.tanh(z_t)
    o_t = jax.nn.sigmoid(o_t)
    m_new = jnp.maximum(f_t + state["m"], i_t)
    finite = jnp.isfinite(state["m"])
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.where(finite, jnp.exp(f_t + state["m"] - m_new), 0.0)
    c = f_p * state["c"] + i_p * z_t
    n = f_p * state["n"] + i_p
    h_t = o_t * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h_t}, h_t


def slstm_forward(cfg: ArchConfig, params, x: Array) -> Array:
    b, L, d = x.shape
    wx = dense(x, params["w"])

    def step(state, wxt):
        return _slstm_step_inner(cfg, params, state, wxt)

    s0 = init_slstm_state(cfg, b)
    _, hs = jax.lax.scan(step, s0, wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, L, d).astype(x.dtype)
    y = rms_norm(hs, params["norm_w"], cfg.norm_eps)
    return dense(y, params["out_proj"])


def slstm_prefill_chunk(cfg: ArchConfig, params, state, x: Array, *,
                        chunk_len, active=None):
    """One prefill chunk resuming from per-slot saved (c, n, m, h) state.

    Same contract as ``mlstm_prefill_chunk``; the recurrent-gate input
    R·h_{t-1} makes sLSTM inherently sequential, so this is the packed
    scan with frozen leaves past chunk_len (bit-exact resume).
    """
    b, c, d = x.shape
    wx = dense(x, params["w"])
    eff = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (b,))
    if active is not None:
        eff = jnp.where(jnp.asarray(active).reshape(b), eff, 0)
    valid = jnp.arange(c)[None, :] < eff[:, None]                  # (B,C)

    def step_fn(st, inner):
        (wxt,) = inner
        return _slstm_step_inner(cfg, params, st, wxt)

    new_state, hs = _masked_scan_resume(
        state, step_fn, (wx.transpose(1, 0, 2),), valid.T, b)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, c, d).astype(x.dtype)
    y = rms_norm(hs, params["norm_w"], cfg.norm_eps)
    return dense(y, params["out_proj"]), new_state


def slstm_step(cfg: ArchConfig, params, state, x: Array):
    wx = dense(x, params["w"])
    state, h_t = _slstm_step_inner(cfg, params, state, wx)
    b, d = x.shape
    y = h_t.reshape(b, d).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    return dense(y, params["out_proj"]), state
