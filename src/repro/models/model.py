"""Top-level model API: init / forward (train) / prefill / decode_step.

All functions are pure and jit-friendly; ``cfg`` is static. The layer
stack runs as ``lax.scan`` over periods (see transformer.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.models.layers import rms_norm, rope_cos_sin

Array = jax.Array


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    return T.init_params(cfg, key, dtype)


def default_plan(cfg: ArchConfig):
    return T.default_plan(cfg)


def embed_input(cfg: ArchConfig, params, batch) -> Array:
    """batch: (B,S) int32 tokens, or (B,S,frontend_dim) embeddings for
    frontend-stub archs (vlm/audio)."""
    if cfg.embed_frontend_stub:
        return batch  # precomputed frame/patch embeddings
    return jnp.take(params["embed"], batch, axis=0)


def unembed(cfg: ArchConfig, params, x: Array) -> Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["embed"])
    return jnp.einsum("...d,dv->...v", x, params["lm_head"])


def _rope(cfg: ArchConfig, positions: Array):
    return rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def forward(cfg: ArchConfig, params, batch, *, plan=None, impl: str = "ref",
            alpha: Array | None = None, remat: bool = False) -> Array:
    """Full-sequence forward -> logits (B, S, V).

    alpha: (num_layers, Hkv) gating parameters for head-identification
    training (None = plain attention).
    """
    plan = plan if plan is not None else T.default_plan(cfg)
    x = embed_input(cfg, params, batch)
    s = x.shape[1]
    rope = _rope(cfg, jnp.arange(s))
    n_per, n_rem = T.layer_layout(cfg)
    p_len = T.period_len(cfg)

    if alpha is not None:
        alpha_blocks = alpha[: n_per * p_len].reshape(n_per, p_len, -1)
    else:
        alpha_blocks = None

    def period_fn(x, xs):
        params_p, plan_p, alpha_p = xs
        for pos in range(p_len):
            a = alpha_p[pos] if alpha_p is not None else None
            x = T.block_train(cfg, pos, params_p[f"pos{pos}"],
                              plan_p[f"pos{pos}"], x, rope, impl=impl,
                              alpha=a)
        return x, ()

    body = jax.checkpoint(period_fn) if remat else period_fn
    if n_per > 0:
        xs = (params["blocks"], plan["blocks"], alpha_blocks)
        x, _ = jax.lax.scan(lambda c, s_: body(c, s_), x, xs)
    for r in range(n_rem):
        a = alpha[n_per * p_len + r] if alpha is not None else None
        x = T.block_train(cfg, r, params["rem"][f"rem{r}"],
                          plan["rem"][f"rem{r}"], x, rope, impl=impl, alpha=a)
    return unembed(cfg, params, x)


def lm_loss(cfg: ArchConfig, params, batch, labels, *, plan=None,
            impl: str = "ref", alpha=None, remat: bool = True) -> Array:
    """Mean next-token cross-entropy. labels: (B, S) int32 (-100 = pad)."""
    logits = forward(cfg, params, batch, plan=plan, impl=impl, alpha=alpha,
                     remat=remat)
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(cfg: ArchConfig, params, batch, *, capacity: int, plan=None,
            impl: str = "ref", layout=None):
    """Process the prompt; returns (last-token logits, ServeState)."""
    plan = plan if plan is not None else T.default_plan(cfg)
    x = embed_input(cfg, params, batch)
    s = x.shape[1]
    rope = _rope(cfg, jnp.arange(s))
    n_per, n_rem = T.layer_layout(cfg)
    p_len = T.period_len(cfg)

    def period_fn(x, xs):
        params_p, plan_p = xs
        caches = {}
        for pos in range(p_len):
            x, c = T.block_prefill(cfg, pos, params_p[f"pos{pos}"],
                                   plan_p[f"pos{pos}"], x, rope,
                                   capacity=capacity, impl=impl,
                                   layout=layout)
            caches[f"pos{pos}"] = c
        return x, caches

    state: dict[str, Any] = {"length": jnp.int32(s), "blocks": {}, "rem": {}}
    if n_per > 0:
        x, caches = jax.lax.scan(
            period_fn, x, (params["blocks"], plan["blocks"]))
        state["blocks"] = caches
    for r in range(n_rem):
        x, c = T.block_prefill(cfg, r, params["rem"][f"rem{r}"],
                               plan["rem"][f"rem{r}"], x, rope,
                               capacity=capacity, impl=impl, layout=layout)
        state["rem"][f"rem{r}"] = c
    logits = unembed(cfg, params, x[:, -1])
    return logits, state


def prefill_chunk(cfg: ArchConfig, params, state, tokens, *, chunk_len,
                  active, plan=None, impl: str = "ref", layout=None):
    """Feed one prompt chunk per slot into the batched serve state
    (chunked, slot-resident prefill — the admission half of the engine's
    mixed prefill+decode step).

    tokens: (B, C) int32 — left-aligned per-slot chunks, padded past
    ``chunk_len`` ((B,), valid tokens per slot). ``active`` (B,) marks
    the slots taking a chunk this step; the rest of the batch (decoding
    or free slots) appends nothing and keeps its length. Each slot's
    chunk starts at its current ``state["length"]``. Returns
    (last-chunk-token logits (B, V), new state) — the logits row of a
    slot whose prompt just completed is its first-token distribution
    (garbage for every other row). C is static, so one compiled program
    serves every chunk schedule (the zero-recompile invariant).
    """
    assert not cfg.embed_frontend_stub, (
        "chunked prefill feeds token chunks through the embedding; "
        "frontend-stub archs use prefill-then-pack admission")
    plan = plan if plan is not None else T.default_plan(cfg)
    start = jnp.asarray(state["length"], jnp.int32).reshape(-1)   # (B,)
    x = jnp.take(params["embed"], tokens, axis=0)                 # (B,C,d)
    cch = tokens.shape[1]
    pos_q = start[:, None] + jnp.arange(cch, dtype=jnp.int32)
    rope = _rope(cfg, pos_q)                                      # (B,C,half)
    chunk_len = jnp.asarray(chunk_len, jnp.int32).reshape(-1)
    active = jnp.asarray(active).reshape(-1)
    n_per, n_rem = T.layer_layout(cfg)
    p_len = T.period_len(cfg)

    def period_fn(x, xs):
        params_p, plan_p, cache_p = xs
        new_caches = {}
        for pos in range(p_len):
            x, c = T.block_prefill_chunk(
                cfg, pos, params_p[f"pos{pos}"], plan_p[f"pos{pos}"], x,
                rope, cache_p[f"pos{pos}"], start=start,
                chunk_len=chunk_len, active=active, impl=impl,
                layout=layout)
            new_caches[f"pos{pos}"] = c
        return x, new_caches

    new_len = jnp.where(active, start + chunk_len, start)
    new_state: dict[str, Any] = {
        "length": new_len.astype(jnp.asarray(state["length"]).dtype),
        "blocks": {}, "rem": {}}
    if n_per > 0:
        x, caches = jax.lax.scan(
            period_fn, x,
            (params["blocks"], plan["blocks"], state["blocks"]))
        new_state["blocks"] = caches
    for r in range(n_rem):
        x, c = T.block_prefill_chunk(
            cfg, r, params["rem"][f"rem{r}"], plan["rem"][f"rem{r}"], x,
            rope, state["rem"][f"rem{r}"], start=start,
            chunk_len=chunk_len, active=active, impl=impl, layout=layout)
        new_state["rem"][f"rem{r}"] = c
    # logits at each slot's LAST valid chunk position (first-token
    # emission for slots whose prompt completed this step)
    idx = jnp.clip(chunk_len - 1, 0, cch - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return unembed(cfg, params, x_last), new_state


def verify_forward(cfg: ArchConfig, params, state, tokens, *, active,
                   need_select, plan=None, impl: str = "ref", layout=None):
    """Speculative verify forward: run k drafted tokens per slot as k
    decode steps in ONE chunked pass over the PRE-append caches
    (attend-before-append; see core/hybrid_attention.py).

    tokens: (B, k) int32 — row 0 is each slot's pending feed token, rows
    1..k-1 the draft; positions are state["length"] .. +k-1. Returns
    (logits (B, k, V), state', stash): logits row j is the target
    distribution at position length+j; state' carries ONLY the refreshed
    page selection/importance (gated by ``need_select``/``active``) with
    KV pages, stream rings, and lengths untouched; ``stash`` holds each
    layer's roped chunk (k, v) for ``verify_commit``. Acceptance decides
    how much of the chunk commits — the cache is never rolled back.
    """
    plan = plan if plan is not None else T.default_plan(cfg)
    start = jnp.asarray(state["length"], jnp.int32).reshape(-1)   # (B,)
    x = jnp.take(params["embed"], tokens, axis=0)                 # (B,k,d)
    kch = tokens.shape[1]
    pos_q = start[:, None] + jnp.arange(kch, dtype=jnp.int32)
    rope = _rope(cfg, pos_q)                                      # (B,k,half)
    active = jnp.asarray(active).reshape(-1)
    need_select = jnp.asarray(need_select).reshape(-1)
    n_per, n_rem = T.layer_layout(cfg)
    p_len = T.period_len(cfg)

    def period_fn(x, xs):
        params_p, plan_p, cache_p = xs
        new_caches, stash_p = {}, {}
        for pos in range(p_len):
            x, c, kv = T.block_verify_chunk(
                cfg, pos, params_p[f"pos{pos}"], plan_p[f"pos{pos}"], x,
                rope, cache_p[f"pos{pos}"], start=start, active=active,
                need_select=need_select, impl=impl, layout=layout)
            new_caches[f"pos{pos}"] = c
            stash_p[f"pos{pos}"] = kv
        return x, (new_caches, stash_p)

    new_state: dict[str, Any] = {"length": state["length"],
                                 "blocks": {}, "rem": {}}
    stash: dict[str, Any] = {"blocks": {}, "rem": {}}
    if n_per > 0:
        x, (caches, stash_b) = jax.lax.scan(
            period_fn, x,
            (params["blocks"], plan["blocks"], state["blocks"]))
        new_state["blocks"] = caches
        stash["blocks"] = stash_b
    for r in range(n_rem):
        x, c, kv = T.block_verify_chunk(
            cfg, r, params["rem"][f"rem{r}"], plan["rem"][f"rem{r}"], x,
            rope, state["rem"][f"rem{r}"], start=start, active=active,
            need_select=need_select, impl=impl, layout=layout)
        new_state["rem"][f"rem{r}"] = c
        stash["rem"][f"rem{r}"] = kv
    return unembed(cfg, params, x), new_state, stash


def verify_commit(cfg: ArchConfig, state, stash, *, accepted, active,
                  plan=None, impl: str = "ref", layout=None):
    """Commit each slot's accepted prefix (``accepted`` (B,), >= 1
    tokens of the verified chunk) into the serve caches from the
    ``verify_forward`` stash, through the same ragged chunk appends a
    sequence of single-token decode appends reduces to. Inactive slots
    commit nothing. Returns the advanced state (length += accepted)."""
    plan = plan if plan is not None else T.default_plan(cfg)
    start = jnp.asarray(state["length"], jnp.int32).reshape(-1)
    accepted = jnp.asarray(accepted, jnp.int32).reshape(-1)
    active = jnp.asarray(active).reshape(-1)
    n_per, n_rem = T.layer_layout(cfg)
    p_len = T.period_len(cfg)

    def period_fn(_, xs):
        plan_p, cache_p, stash_p = xs
        new_caches = {}
        for pos in range(p_len):
            new_caches[f"pos{pos}"] = T.block_verify_append(
                cfg, pos, plan_p[f"pos{pos}"], cache_p[f"pos{pos}"],
                stash_p[f"pos{pos}"], start=start, accepted=accepted,
                active=active, impl=impl, layout=layout)
        return (), new_caches

    new_len = jnp.where(active, start + accepted, start)
    new_state: dict[str, Any] = {
        "length": new_len.astype(jnp.asarray(state["length"]).dtype),
        "blocks": {}, "rem": {}}
    if n_per > 0:
        _, caches = jax.lax.scan(
            period_fn, (),
            (plan["blocks"], state["blocks"], stash["blocks"]))
        new_state["blocks"] = caches
    for r in range(n_rem):
        new_state["rem"][f"rem{r}"] = T.block_verify_append(
            cfg, r, plan["rem"][f"rem{r}"], state["rem"][f"rem{r}"],
            stash["rem"][f"rem{r}"], start=start, accepted=accepted,
            active=active, impl=impl, layout=layout)
    return new_state


def decode_step(cfg: ArchConfig, params, state, token, *, plan=None,
                do_select: bool = True, impl: str = "ref", layout=None,
                active=None, need_select=None):
    """One decode step.

    token: (B,) int32 (or (B, frontend_dim) embeddings for stub archs).
    Returns (logits (B, V), new state).

    ``state["length"]`` is a scalar on the uniform (lockstep) path and a
    (B,) per-slot vector on the continuous-batching ragged path, where
    ``active`` ((B,) bool) marks live slots — inactive slots neither
    append to their caches nor advance their length — and ``need_select``
    ((B,) bool, select variant only) is the per-slot share-window phase
    mask. Logits of inactive slots are garbage and must be ignored.
    """
    plan = plan if plan is not None else T.default_plan(cfg)
    length = state["length"]
    if cfg.embed_frontend_stub:
        x = token
    else:
        x = jnp.take(params["embed"], token, axis=0)
    # rope at each slot's own position: (1, half) lockstep / (B, half) ragged
    rope1 = _rope(cfg, jnp.reshape(length, (-1,)))
    rope1 = (rope1[0][:, None], rope1[1][:, None])  # (·, 1, half) broadcast
    n_per, n_rem = T.layer_layout(cfg)
    p_len = T.period_len(cfg)

    def period_fn(x, xs):
        params_p, plan_p, cache_p = xs
        new_caches = {}
        for pos in range(p_len):
            x, c = T.block_decode(cfg, pos, params_p[f"pos{pos}"],
                                  plan_p[f"pos{pos}"], x, rope1,
                                  cache_p[f"pos{pos}"], length=length,
                                  do_select=do_select, impl=impl,
                                  layout=layout, active=active,
                                  need_select=need_select)
            new_caches[f"pos{pos}"] = c
        return x, new_caches

    new_len = length + 1
    if active is not None:
        new_len = jnp.where(active, new_len, length)
    new_state: dict[str, Any] = {"length": new_len, "blocks": {},
                                 "rem": {}}
    if n_per > 0:
        x, caches = jax.lax.scan(
            period_fn, x,
            (params["blocks"], plan["blocks"], state["blocks"]))
        new_state["blocks"] = caches
    for r in range(n_rem):
        x, c = T.block_decode(cfg, r, params["rem"][f"rem{r}"],
                              plan["rem"][f"rem{r}"], x, rope1,
                              state["rem"][f"rem{r}"], length=length,
                              do_select=do_select, impl=impl, layout=layout,
                              active=active, need_select=need_select)
        new_state["rem"][f"rem{r}"] = c
    logits = unembed(cfg, params, x)
    return logits, new_state
