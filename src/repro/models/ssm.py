"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)
recurrent step for decode. Used by the zamba2 hybrid architecture.

State-space recurrence per head h with P = head_dim, N = state_dim:

    S_t = dA_t · S_{t-1} + dt_t · B_t ⊗ x_t          S: (N, P)
    y_t = C_t · S_t + D_h · x_t

with dA_t = exp(-exp(A_log_h) · dt_t), dt_t = softplus(dt_raw + bias).
B/C are shared across heads (single group). The chunked form computes
intra-chunk contributions with a causal decay matrix (MXU-friendly
einsums) and carries inter-chunk state with a scan — the TPU-native
re-blocking of the paper'd GPU SSD kernel.

Sharding note: the canonical fused in_proj emits one (d, 2·inner+2N+H)
matrix whose z/x/B/C/dt split points do not align to TP shard boundaries —
GSPMD re-gathers the full projection every layer (measured 374 GB/step on
zamba2 train_4k). The projections are therefore FACTORED per stream
(w_z, w_x, w_B, w_C, w_dt) with separate depthwise convs — mathematically
identical, shard-aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, init_dense, rms_norm

Array = jax.Array


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    n_heads = inner // s.head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_z": init_dense(ks[0], d, inner, dtype=dtype),
        "w_x": init_dense(ks[1], d, inner, dtype=dtype),
        "w_B": init_dense(ks[2], d, s.state_dim, dtype=dtype),
        "w_C": init_dense(ks[3], d, s.state_dim, dtype=dtype),
        "w_dt": init_dense(ks[4], d, n_heads, dtype=dtype),
        "conv_x": (jax.random.normal(ks[5], (s.conv_dim, inner)) * 0.1
                   ).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (s.conv_dim, s.state_dim)) * 0.1
                   ).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (s.conv_dim, s.state_dim)) * 0.1
                   ).astype(dtype),
        "conv_bx": jnp.zeros((inner,), dtype),
        "conv_bB": jnp.zeros((s.state_dim,), dtype),
        "conv_bC": jnp.zeros((s.state_dim,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_w": jnp.zeros((inner,), dtype),
        "out_proj": init_dense(ks[0], inner, d, dtype=dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array):
    """Depthwise causal conv over time. x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b)


def _project(cfg: ArchConfig, params, x):
    """x: (B, L, d) -> (z, xs, B, C, dt_raw) with per-stream causal convs."""
    z = dense(x, params["w_z"])
    xs = _causal_conv(dense(x, params["w_x"]), params["conv_x"],
                      params["conv_bx"])
    B = _causal_conv(dense(x, params["w_B"]), params["conv_B"],
                     params["conv_bB"])
    C = _causal_conv(dense(x, params["w_C"]), params["conv_C"],
                     params["conv_bC"])
    dt_raw = dense(x, params["w_dt"])
    return z, xs, B, C, dt_raw


def mamba2_forward(cfg: ArchConfig, params, x: Array) -> Array:
    """x: (B, L, d) -> (B, L, d). Chunked SSD."""
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    n_heads = inner // s.head_dim
    bsz, L, _ = x.shape

    z, xs, B, C, dt_raw = _project(cfg, params, x)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])      # (B,L,H)
    a = -jnp.exp(params["A_log"])                                  # (H,)
    log_da = a[None, None, :] * dt                                 # (B,L,H) <0

    q = min(s.chunk, L)
    pad = (-L) % q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_da = jnp.pad(log_da, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // q

    xh = xs.reshape(bsz, nc, q, n_heads, s.head_dim)
    Bc = B.reshape(bsz, nc, q, s.state_dim)
    Cc = C.reshape(bsz, nc, q, s.state_dim)
    dtc = dt.reshape(bsz, nc, q, n_heads)
    ld = log_da.reshape(bsz, nc, q, n_heads)
    G = jnp.cumsum(ld, axis=2)                                     # (B,nc,Q,H)

    # intra-chunk: y_i += sum_{j<=i} (G_i/G_j) dt_j (C_i·B_j) x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    ii = jnp.arange(q)[:, None]
    jj = jnp.arange(q)[None, :]
    causal = (jj <= ii)[None, None, :, :, None]
    logw = G[:, :, :, None, :] - G[:, :, None, :, :]               # (B,nc,i,j,H)
    w = jnp.where(causal, jnp.exp(logw), 0.0)
    w = w * cb[..., None] * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w,
                         xh.astype(jnp.float32))

    # chunk states: S_c = sum_j exp(G_last - G_j) dt_j B_j ⊗ x_j
    from repro.runtime import hints

    decay_to_end = jnp.exp(G[:, :, -1:, :] - G)                    # (B,nc,Q,H)
    sc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_to_end * dtc,
                    Bc.astype(jnp.float32), xh.astype(jnp.float32))
    chunk_decay = jnp.exp(G[:, :, -1, :])                          # (B,nc,H)

    def scan_fn(s_prev, inp):
        dec, s_chunk = inp
        s_new = dec[:, :, None, None] * s_prev + s_chunk
        return s_new, s_prev

    s0 = jnp.zeros((bsz, n_heads, s.state_dim, s.head_dim), jnp.float32)
    # pin the per-chunk state stacks head-sharded over 'model' — GSPMD
    # otherwise replicates the scan xs/ys (measured 181 GB/step all-gather
    # on zamba2 train_4k)
    sc_t = hints.pin(sc.transpose(1, 0, 2, 3, 4),
                     None, "batch", "model", None, None)
    dec_t = hints.pin(chunk_decay.transpose(1, 0, 2), None, "batch", "model")
    s0 = hints.pin(s0, "batch", "model", None, None)
    _, s_init = jax.lax.scan(scan_fn, s0, (dec_t, sc_t))
    s_init = hints.pin(s_init, None, "batch", "model", None, None)
    s_init = s_init.transpose(1, 0, 2, 3, 4)                       # (B,nc,H,N,P)
    s_init = hints.pin(s_init, "batch", None, "model", None, None)

    # inter-chunk: y_i += G_i * C_i · S_init
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc.astype(jnp.float32),
                         s_init, jnp.exp(G))
    y = (y_intra + y_inter).reshape(bsz, nc * q, n_heads, s.head_dim)
    y = y + xh.reshape(bsz, nc * q, n_heads, s.head_dim) * params["D"][None, None, :, None]
    y = y[:, :L].reshape(bsz, L, inner).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    return dense(y, params["out_proj"])


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1) per step)
# ---------------------------------------------------------------------------


def init_mamba2_state(cfg: ArchConfig, bsz: int, dtype=jnp.float32):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    n_heads = inner // s.head_dim
    return {
        "ssm": jnp.zeros((bsz, n_heads, s.state_dim, s.head_dim), jnp.float32),
        "conv_x": jnp.zeros((bsz, s.conv_dim - 1, inner), dtype),
        "conv_B": jnp.zeros((bsz, s.conv_dim - 1, s.state_dim), dtype),
        "conv_C": jnp.zeros((bsz, s.conv_dim - 1, s.state_dim), dtype),
    }


def _conv_step(hist: Array, new: Array, w: Array, b: Array):
    """hist: (B, K-1, C); new: (B, C) -> (out (B, C), hist')."""
    window = jnp.concatenate([hist, new[:, None, :]], axis=1)
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)) + b
    return jax.nn.silu(out).astype(new.dtype), window[:, 1:, :]


def mamba2_step(cfg: ArchConfig, params, state, x: Array):
    """x: (B, d) one token -> (y (B, d), new state)."""
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    n_heads = inner // s.head_dim

    z = dense(x, params["w_z"])
    xs, cx = _conv_step(state["conv_x"], dense(x, params["w_x"]),
                        params["conv_x"], params["conv_bx"])
    B, cB = _conv_step(state["conv_B"], dense(x, params["w_B"]),
                       params["conv_B"], params["conv_bB"])
    C, cC = _conv_step(state["conv_C"], dense(x, params["w_C"]),
                       params["conv_C"], params["conv_bC"])
    dt_raw = dense(x, params["w_dt"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(a[None, :] * dt)                                    # (B,H)
    xhead = xs.reshape(-1, n_heads, s.head_dim).astype(jnp.float32)
    outer = jnp.einsum("bn,bhp->bhnp", B.astype(jnp.float32), xhead)
    ssm = da[:, :, None, None] * state["ssm"] + dt[:, :, None, None] * outer
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), ssm)
    y = y + xhead * params["D"][None, :, None]
    y = y.reshape(-1, inner).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    new_state = {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return dense(y, params["out_proj"]), new_state


def mamba2_prefill_chunk(cfg: ArchConfig, params, state, x: Array, *,
                         chunk_len, active=None):
    """One prefill chunk resuming from per-slot saved recurrent state.

    x: (B, C, d) — the chunk's block inputs; state: as
    ``init_mamba2_state`` (conv_* hold the PRE-conv inputs of the last
    K-1 consumed positions, ssm the (H, N, P) SSD state). chunk_len:
    scalar or (B,) valid tokens in the chunk; active: (B,) bool. Returns
    (y (B, C, d), state').

    The chunk is processed as ONE SSD chunk resumed from ``state`` (the
    serving chunk is bounded, so the O(C²) intra-chunk decay matrix is
    the same re-blocking mamba2_forward uses per chunk). Ragged tails
    and inactive slots are identity on the state: dt is zeroed past
    chunk_len (decay exp(0) = 1, contribution 0 — exactly the zero-pad
    treatment in mamba2_forward) and the conv-history gather at eff = 0
    returns the old window bit-exactly. Outputs past chunk_len are
    garbage the caller ignores. Numerics: resuming chunk-by-chunk
    reassociates float sums vs one packed pass, so chunked and packed
    prefill agree to float tolerance.
    """
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    n_heads = inner // s.head_dim
    bsz, c, _ = x.shape
    k = s.conv_dim
    eff = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (bsz,))
    if active is not None:
        eff = jnp.where(jnp.asarray(active).reshape(bsz), eff, 0)

    z = dense(x, params["w_z"])
    dt_raw = dense(x, params["w_dt"])

    def conv_resume(hist, pre, w, b):
        # causal conv over [carried history ∥ chunk]: position t sees
        # buf[t : t+K] — identical to _causal_conv's left-pad when the
        # history is zeros (fresh slot)
        buf = jnp.concatenate([hist.astype(pre.dtype), pre], axis=1)
        out = sum(buf[:, i: i + c, :] * w[i][None, None, :]
                  for i in range(k))
        out = jax.nn.silu(out + b)
        # new history: pre-conv inputs of the last K-1 consumed
        # positions; eff = 0 gathers the old window back bit-exactly
        idx = eff[:, None] + jnp.arange(k - 1)
        hist_new = jnp.take_along_axis(buf, idx[:, :, None], axis=1)
        return out, hist_new.astype(hist.dtype)

    xs, hx = conv_resume(state["conv_x"], dense(x, params["w_x"]),
                         params["conv_x"], params["conv_bx"])
    B, hB = conv_resume(state["conv_B"], dense(x, params["w_B"]),
                        params["conv_B"], params["conv_bB"])
    C, hC = conv_resume(state["conv_C"], dense(x, params["w_C"]),
                        params["conv_C"], params["conv_bC"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])       # (B,C,H)
    valid = jnp.arange(c)[None, :] < eff[:, None]                  # (B,C)
    dt = jnp.where(valid[..., None], dt, 0.0)
    a = -jnp.exp(params["A_log"])
    log_da = a[None, None, :] * dt
    G = jnp.cumsum(log_da, axis=1)                                 # (B,C,H)

    xh = xs.reshape(bsz, c, n_heads, s.head_dim).astype(jnp.float32)
    Bc = B.astype(jnp.float32)
    Cc = C.astype(jnp.float32)
    cb = jnp.einsum("bin,bjn->bij", Cc, Bc)
    causal = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
              )[None, :, :, None]
    logw = G[:, :, None, :] - G[:, None, :, :]                     # (B,i,j,H)
    w = jnp.where(causal, jnp.exp(logw), 0.0) * cb[..., None] \
        * dt[:, None, :, :]
    y = jnp.einsum("bijh,bjhp->bihp", w, xh)
    # inter-chunk: resumed state seen through each position's decay
    y = y + jnp.einsum("bin,bhnp,bih->bihp", Cc, state["ssm"],
                       jnp.exp(G))
    # carry: S' = exp(G_last)·S + Σ_j exp(G_last - G_j) dt_j B_j ⊗ x_j
    # (masked positions contribute decay 1 / weight 0, so G_last is the
    # decay over exactly the valid prefix)
    decay_to_end = jnp.exp(G[:, -1:, :] - G)
    sc = jnp.einsum("bjh,bjn,bjhp->bhnp", decay_to_end * dt, Bc, xh)
    ssm = jnp.exp(G[:, -1, :])[:, :, None, None] * state["ssm"] + sc

    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(bsz, c, inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    new_state = {"ssm": ssm, "conv_x": hx, "conv_B": hB, "conv_C": hC}
    return dense(y, params["out_proj"]), new_state


def mamba2_final_state(cfg: ArchConfig, params, x: Array):
    """Final (ssm, conv_*) state after consuming x: (B, L, d)."""
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    n_heads = inner // s.head_dim
    bsz, L, _ = x.shape
    z, xs, B, C, dt_raw = _project(cfg, params, x)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["A_log"])
    log_da = a[None, None, :] * dt
    q = min(s.chunk, L)
    pad = (-L) % q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_da = jnp.pad(log_da, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // q
    xh = xs.reshape(bsz, nc, q, n_heads, s.head_dim)
    Bc = B.reshape(bsz, nc, q, s.state_dim)
    dtc = dt.reshape(bsz, nc, q, n_heads)
    ld = log_da.reshape(bsz, nc, q, n_heads)
    G = jnp.cumsum(ld, axis=2)
    decay_to_end = jnp.exp(G[:, :, -1:, :] - G)
    sc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_to_end * dtc,
                    Bc.astype(jnp.float32), xh.astype(jnp.float32))
    chunk_decay = jnp.exp(G[:, :, -1, :])

    def scan_fn(s_prev, inp):
        dec, s_chunk = inp
        return dec[:, :, None, None] * s_prev + s_chunk, ()

    s0 = jnp.zeros((bsz, n_heads, s.state_dim, s.head_dim), jnp.float32)
    s_fin, _ = jax.lax.scan(
        scan_fn, s0,
        (chunk_decay.transpose(1, 0, 2), sc.transpose(1, 0, 2, 3, 4)))
    k = s.conv_dim - 1
    # conv states hold PRE-conv inputs of the last K-1 positions
    pre_x = dense(x, params["w_x"])[:, L - k:, :]
    pre_B = dense(x, params["w_B"])[:, L - k:, :]
    pre_C = dense(x, params["w_C"])[:, L - k:, :]
    return {"ssm": s_fin, "conv_x": pre_x, "conv_B": pre_B, "conv_C": pre_C}
