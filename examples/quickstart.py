"""Quickstart: train a small model on synthetic data, then serve it with
H²EAL hybrid sparse attention.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.data import lm_batch
from repro.launch.serve import generate
from repro.models import model as M
from repro.optim import adamw
from repro.runtime import train as train_rt


def main():
    cfg = reduced(get_arch("smollm-360m"))
    print(f"arch: {cfg.name} ({cfg.num_layers}L d={cfg.d_model} "
          f"heads={cfg.num_heads}/{cfg.num_kv_heads})")

    # --- train ---------------------------------------------------------
    tcfg = train_rt.TrainConfig(remat=False, lr=1e-3, total_steps=60)
    step_fn = jax.jit(train_rt.make_train_step(cfg, tcfg))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    for step in range(60):
        batch = lm_batch(jnp.int32(step), batch=8, seq=96,
                         vocab=cfg.vocab_size)
        params, opt, m = step_fn(params, opt, batch, jnp.int32(step))
        if step % 20 == 0 or step == 59:
            print(f"  step {step:3d}  loss {float(m['loss']):.4f}")

    # --- serve with hybrid sparse attention ----------------------------
    prompts = lm_batch(jnp.int32(999), batch=2, seq=96,
                       vocab=cfg.vocab_size)["tokens"]
    toks, stats = generate(cfg, params, prompts, gen=16, capacity=160)
    print(f"serve (H²EAL): {stats['tokens_per_s']:.1f} tok/s")
    toks_full, _ = generate(cfg, params, prompts, gen=16, capacity=160,
                            h2eal=False)
    agree = (toks == toks_full).mean()
    print(f"token agreement sparse vs full on a trained model: "
          f"{float(agree):.2f}")
    print(f"generated: {toks[0].tolist()}")


if __name__ == "__main__":
    main()
