"""Head identification via gating (paper §IV-A.1, DuoAttention-style).

A tiny model is trained on a retrieval task (needle-in-a-haystack copy)
with the α-gated attention mix:

    Attn = α · Full + (1-α) · Streaming,   loss = task + λ‖α‖₁

Heads that the task needs for long-range retrieval keep α high; the rest
collapse to streaming. The resulting per-layer permutation (retrieval
heads first) is exactly the 'plan' the serving stack consumes.

    PYTHONPATH=src python examples/head_identification.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core import gating
from repro.data import niah_batch
from repro.models import model as M
from repro.optim import adamw


def main():
    cfg = reduced(get_arch("smollm-360m"),
                  num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=128, head_dim=16)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    alpha = gating.init_alpha(cfg.num_layers, cfg.num_kv_heads)

    lam = 2e-3

    def loss_fn(params, alpha, tokens, answer):
        logits = M.forward(cfg, params, tokens, alpha=alpha, remat=False)
        logp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
        task = -jnp.take_along_axis(logp, answer[:, None], axis=-1).mean()
        return gating.gating_loss(task, alpha, lam), task

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1),
                                         has_aux=True))
    opt_p = adamw.init_state(params)
    opt_a = adamw.init_state(alpha)
    pcfg = adamw.AdamWConfig(lr=2e-3, weight_decay=0.0)
    acfg = adamw.AdamWConfig(lr=2e-2, weight_decay=0.0)

    for step in range(150):
        batch = niah_batch(jnp.int32(step), batch=16, seq=64,
                           vocab=cfg.vocab_size, depth_frac=0.4)
        (loss, task), (gp, ga) = grad_fn(params, alpha, batch["tokens"],
                                         batch["answer"])
        params, opt_p, _ = adamw.apply_updates(params, gp, opt_p, pcfg)
        alpha, opt_a, _ = adamw.apply_updates(alpha, ga, opt_a, acfg)
        alpha = gating.clip_alpha(alpha)
        if step % 30 == 0 or step == 149:
            print(f"step {step:3d}  task {float(task):.3f}  "
                  f"alpha {jnp.round(alpha, 2).tolist()}")

    perms = gating.classify_heads(alpha, cfg.h2eal.static_sparsity)
    print("\nper-layer kv-head order (retrieval first):")
    for l in range(cfg.num_layers):
        print(f"  layer {l}: {perms[l].tolist()}  "
              f"(α = {jnp.round(alpha[l], 2).tolist()})")
    n_r = cfg.num_kv_heads - round(cfg.num_kv_heads
                                   * cfg.h2eal.static_sparsity)
    kept = float(jnp.mean(jnp.sort(alpha, axis=1)[:, -n_r:]))
    dropped = float(jnp.mean(jnp.sort(alpha, axis=1)[:, :-n_r]))
    print(f"\nmean α of retained retrieval heads: {kept:.2f}; "
          f"of streaming heads: {dropped:.2f}")


if __name__ == "__main__":
    main()
