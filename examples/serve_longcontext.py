"""Long-context serving: H²EAL vs full attention on a reduced model,
plus the hbsim projection of the same workload on the paper's edge
accelerator.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.configs.base import H2ealConfig
from repro.hbsim import attention_decode, e2e_decode
from repro.launch.serve import generate
from repro.models import model as M


def main():
    cfg = reduced(get_arch("smollm-360m"))
    cfg = dataclasses.replace(cfg, h2eal=H2ealConfig(
        sink=4, local=64, page_size=16, select_budget=256, share_window=4))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ctx = 1024
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, ctx), 0,
                                 cfg.vocab_size)

    print(f"== reduced model, context {ctx}, decode 32 tokens ==")
    toks_h, st_h = generate(cfg, params, prompts, gen=32,
                            capacity=ctx + 64)
    toks_f, st_f = generate(cfg, params, prompts, gen=32,
                            capacity=ctx + 64, h2eal=False)
    print(f"  H²EAL : {st_h['decode_s']:.2f}s decode "
          f"({st_h['tokens_per_s']:.1f} tok/s)")
    print(f"  full  : {st_f['decode_s']:.2f}s decode "
          f"({st_f['tokens_per_s']:.1f} tok/s)")
    agree = float((np.asarray(toks_h) == np.asarray(toks_f)).mean())
    print(f"  token agreement: {agree:.2f} (untrained weights)")

    print("\n== hbsim projection: LLaMA2-7B decode on the HB edge chip ==")
    full_cfg = get_arch("llama2-7b")
    for seq in (65536, 262144):
        f = e2e_decode(full_cfg, seq, "full")
        h = e2e_decode(full_cfg, seq, "h2eal")
        att_f = attention_decode(full_cfg, seq, "full")
        att_h = attention_decode(full_cfg, seq, "h2eal")
        print(f"  ctx {seq//1024:4d}k: full {f['tokens_per_s']:6.1f} tok/s"
              f" -> H²EAL {h['tokens_per_s']:6.1f} tok/s  "
              f"(attention speedup "
              f"{att_f['latency_s']/att_h['latency_s']:.1f}x)")


if __name__ == "__main__":
    main()
