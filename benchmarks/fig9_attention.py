"""Fig 9: normalized attention speedup + energy efficiency.

Three models x decode sequence lengths x {full, sparse w/o balance,
H²EAL}, on the hbsim cycle model. share_window=1 (per-step selection, the
paper's micro-benchmark setting).
"""
import dataclasses

from repro.configs import get_arch
from repro.hbsim import attention_decode

MODELS = ("mistral-7b", "llama2-7b", "llama3-8b")
SEQS = (16384, 65536, 262144)
PAPER_256K = {  # speedup vs full @256k, energy-eff vs full @256k
    "mistral-7b": (28.09, 69.20),
    "llama2-7b": (48.21, 73.48),
    "llama3-8b": (28.20, 70.45),
}


def run(csv=True):
    rows = []
    for name in MODELS:
        cfg = get_arch(name)
        h2 = dataclasses.replace(cfg.h2eal, share_window=1)
        for seq in SEQS:
            f = attention_decode(cfg, seq, "full", h2=h2)
            u = attention_decode(cfg, seq, "sparse_unbalanced", h2=h2)
            h = attention_decode(cfg, seq, "h2eal", h2=h2)
            speed = f["latency_s"] / h["latency_s"]
            bal = u["latency_s"] / h["latency_s"]
            en = f["energy_j"] / h["energy_j"]
            rows.append((name, seq, speed, bal, en))
            if csv:
                print(f"fig9,{name},{seq},{speed:.2f},{bal:.2f},{en:.2f}")
    if csv:
        for name, (ps, pe) in PAPER_256K.items():
            r = next(x for x in rows if x[0] == name and x[1] == 262144)
            print(f"fig9_vs_paper,{name},speedup,{r[2]:.1f},paper,{ps}")
            print(f"fig9_vs_paper,{name},energy,{r[4]:.1f},paper,{pe}")
    return rows


if __name__ == "__main__":
    run()
