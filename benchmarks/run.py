"""Benchmark harness: one module per paper table/figure.

Prints ``name,...`` CSV rows. The roofline table (EXPERIMENTS.md) is
produced separately by ``repro.launch.dryrun`` + ``benchmarks/roofline.py``
(it needs the 512-fake-device environment).
"""


def main() -> None:
    from benchmarks import fig9_attention, table3_e2e, fig11_balance
    from benchmarks import fig13_sparsity, kernels_micro

    print("# fig9: attention speedup/energy (hbsim, share_window=1)")
    fig9_attention.run()
    print("# table3: end-to-end throughput/energy (hbsim)")
    table3_e2e.run()
    print("# fig11: balance ablation (hbsim)")
    fig11_balance.run()
    print("# fig13 proxies: logit fidelity + NIAH selection recall")
    fig13_sparsity.run()
    print("# kernel micro-benchmarks (host CPU, ref impls)")
    kernels_micro.run()


if __name__ == "__main__":
    main()
