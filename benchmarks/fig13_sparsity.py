"""Fig 13 proxy: accuracy vs static sparsity + NIAH selection recall.

No LLaMA3/LongBench offline, so two measurable proxies with the exact
algorithm:
  (a) logit fidelity: cosine(prefill logits, full-attention logits) on a
      reduced model while sweeping static_sparsity — the Fig 13 trade-off
      curve shape;
  (b) NIAH selection recall: plant a needle key at depth x context
      position; query with the key; measure whether page selection ranks
      the needle's page into the top-k (the mechanism NIAH accuracy rests
      on) — no trained weights required.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.configs.base import H2ealConfig
from repro.core import paging
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def logit_fidelity(csv=True):
    cfg = reduced(get_arch("smollm-360m"))
    params = M.init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (4, 96), 0, cfg.vocab_size)
    full = dataclasses.replace(cfg, h2eal=H2ealConfig(enabled=False))
    lg_f, _ = M.prefill(full, params, prompts, capacity=128)
    b = np.asarray(lg_f, np.float64)
    out = []
    for sp in (0.0, 0.25, 0.5, 0.75, 1.0):
        h2 = H2ealConfig(sink=2, local=16, page_size=8, select_budget=32,
                         share_window=2, static_sparsity=sp)
        cfg_s = dataclasses.replace(cfg, h2eal=h2)
        lg_s, _ = M.prefill(cfg_s, params, prompts, capacity=128)
        a = np.asarray(lg_s, np.float64)
        cos = float(np.mean(np.sum(a * b, -1) /
                            (np.linalg.norm(a, axis=-1)
                             * np.linalg.norm(b, axis=-1))))
        out.append((sp, cos))
        if csv:
            print(f"fig13_fidelity,static_sparsity,{sp},logit_cos,{cos:.4f}")
    return out


def niah_selection_recall(csv=True, ctx_lens=(512, 1024, 2048),
                          depths=(0.1, 0.3, 0.5, 0.7, 0.9)):
    """Does top-k page selection retrieve the needle's page?"""
    d = 64
    page = 32
    h2 = H2ealConfig(sink=4, local=64, page_size=page, select_budget=128,
                     share_window=1)
    top_k = h2.top_k_pages
    results = []
    for s in ctx_lens:
        n_pages = s // page
        for depth in depths:
            hits = 0
            trials = 20
            for t in range(trials):
                k1, k2 = jax.random.split(
                    jax.random.fold_in(KEY, t * 1000 + s + int(depth * 100)))
                keys = jax.random.normal(k1, (1, 1, s, d))
                needle = jax.random.normal(k2, (1, 1, d)) * 2.0
                pos = int(s * depth)
                keys = keys.at[:, :, pos].set(needle[:, 0])
                q = needle  # query == needle key (retrieval semantics)
                kp = keys.reshape(1, 1, n_pages, page, d)
                tau_min = kp.min(axis=3)
                tau_max = kp.max(axis=3)
                page_start = jnp.arange(n_pages, dtype=jnp.int32)[None, None] * page
                page_start = jnp.broadcast_to(page_start, (1, 1, n_pages))
                scores = paging.score_pages(
                    q, tau_min, tau_max, page_start, jnp.int32(s),
                    sink=h2.sink, local=h2.local, page=page)
                sel = paging.select_pages(scores, top_k)
                needle_page = pos // page
                # needle inside sink/local region counts as covered
                first_local = max(s - h2.local, 0) // page
                covered = (needle_page < 1 or needle_page >= first_local or
                           needle_page in np.asarray(sel[0, 0]).tolist())
                hits += bool(covered)
            recall = hits / trials
            results.append((s, depth, recall))
            if csv:
                print(f"fig13_niah,ctx,{s},depth,{depth},recall,{recall:.2f}")
    return results


def run(csv=True):
    a = logit_fidelity(csv)
    b = niah_selection_recall(csv)
    return {"fidelity": a, "niah": b}


if __name__ == "__main__":
    run()
