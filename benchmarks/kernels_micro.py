"""Kernel micro-benchmarks: us_per_call of the jitted reference ops on
this host (CPU). The Pallas kernels target TPU; on CPU we time the ref
implementations that the dry-run lowers, which is what XLA's cost model
sees. Derived column = GB/s effective for memory-bound ops."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(f, *args, iters=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv=True):
    key = jax.random.PRNGKey(0)
    rows = []
    # flash attention prefill tile
    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    us = _time(f, q, k, v)
    rows.append(("flash_attention_1k", us, f"{2*2*1024*1024*64*8/us/1e3:.1f}MFLOP/s"))
    # paged decode attention
    q2 = jax.random.normal(key, (8, 8, 64))
    k2 = jax.random.normal(key, (8, 2, 4096, 64))
    valid = jnp.ones((8, 2, 4096), bool)
    g = jax.jit(lambda q, k, v, m: ref.paged_attention_ref(q, k, v, m))
    us = _time(g, q2, k2, k2, valid)
    bytes_ = 8 * 2 * 4096 * 64 * 4 * 2
    rows.append(("paged_attention_4k", us, f"{bytes_/us/1e3:.1f}GB/s"))
    # page scoring
    tau = jax.random.normal(key, (8, 2, 1024, 64))
    h = jax.jit(lambda q, a, b: ref.page_score_ref(q, a, b))
    us = _time(h, q2, tau, tau)
    rows.append(("page_score_1kpages", us,
                 f"{8*2*1024*64*4*2/us/1e3:.1f}GB/s"))
    if csv:
        for name, us, derived in rows:
            print(f"kernel,{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
