"""Continuous batching vs lockstep batching at equal token budget.

Workload: N requests with bucketed prompt lengths and ragged generation
lengths (seeded). Two ways to serve it:

  lockstep — the pre-engine driver: group requests into fixed batches of
             ``max_batch``, pad prompts to the largest bucket, run every
             group for its LONGEST member's generation length (finished
             slots keep burning decode steps).
  ragged   — repro.serving.Engine: slots retire as soon as their request
             finishes and are immediately backfilled from the queue.

Both serve exactly the same requests (equal useful-token budget), so
tok/s is directly comparable. The engine also must not recompile after
warmup: jit cache sizes are captured post-warmup and asserted stable
through the measured phase.

``--layout`` takes a comma-separated list of core/layouts registry
entries and produces one ragged row per layout (page-sharding layouts
get balance-aware admission automatically): ``coplace_shmap`` runs the
engine under shard_map memory-compute co-placement (pages sharded over
the mesh 'model' axis, paper §IV-B), ``interleave`` under GSPMD
within-page token striping (paper Fig 7b) — the multi-device rows. The
no-recompile check applies to every row. Force a multi-device CPU run
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--prefill-chunk N`` adds, per layout, a chunked-prefill engine row
(admission interleaved with decode, ≤ N prompt tokens per engine step
through the layout protocol) with a ``tokens_match_packed`` check
against the prefill-then-pack row — same admission trace, token-exact
off argmax ties.

``--arrival poisson`` runs the bursty-arrival LATENCY harness instead
of the batch drain: seeded Poisson arrivals with periodic max-bucket
long prompts, engine driven step-by-step with a device sync so the
per-step timestamps are honest. Reports p50/p99 time-to-first-token and
inter-token latency for packed vs chunked admission, plus
``decode_tokens_during_long_prefill`` — the step-exact no-head-of-line
metric (tokens other slots emitted while a long prompt was being
admitted: always 0 for the atomic prefill-then-pack, > 0 for chunked).
On CPU the wall-clock percentiles are dispatch-noise bound (correctness
rows, like the interpret-mode pallas rows); the step-exact metric is
the portable signal. See EXPERIMENTS.md §Serving experiments.

``--json PATH`` additionally writes the machine-readable row list
(tok/s per layout x impl x admission mode, occupancy, recompile flags,
latency percentiles) — the BENCH_serve.json artifact; scripts/ci.sh
smokes this invocation so the perf trajectory is captured on every full
CI run.

``--rebalance`` adds the live slot-migration row pair: a churn workload
(ragged prompts AND ragged budgets) served with rebalance off vs the
retire-triggered planner (sched/cost.py + sched/rebalance.py). The
rebalanced row must reproduce the off row token-for-token
(``tokens_match_norebalance``) and strictly reduce the cost-model bank
imbalance at the rebalance checks (``load_imbalance_pre`` vs
``load_imbalance_post`` — the bench_bands.json imbalance gate), with
the migration NoC traffic priced by hbsim.rebalance_overhead.

``--decode-window w`` adds the fused decode-window row trio (PR 10) on
a widened share window: a lockstep baseline for that config, a per-step
engine row, and the ``Engine(decode_window=w)`` row whose reuse steps
between selection boundaries run as ONE dispatched scan — with a
``tokens_match_unfused`` exact check against the per-step row, the
dispatch counters (``dispatches``, ``steps_per_dispatch``), and the
fused >= per-step tokens/s ratio gated in bench_bands.json.

``--attn-impl pallas`` adds the ref-vs-pallas comparison row: the same
workload is served a second time with the Pallas attention kernels
(partial attention + fused combine under coplace_shmap; interpret mode
off-TPU, so the CPU row is a correctness row, not a perf row — see
EXPERIMENTS.md). It reports tok/s for both impls, whether the greedy
token traces match (exact-tie caveat in EXPERIMENTS.md), and the
pallas engine's own no-recompile check.

Run: PYTHONPATH=src python benchmarks/serve_throughput.py
     PYTHONPATH=src python benchmarks/serve_throughput.py \
         --layout coplace_shmap --attn-impl pallas
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_requests(cfg, *, n, buckets, gen_min, gen_max, seed):
    from repro.launch.serve import make_ragged_requests

    return make_ragged_requests(cfg, n=n, prompt_buckets=buckets,
                                gen_min=gen_min, gen_max=gen_max, seed=seed)


def make_lockstep_runner(cfg, params, *, capacity):
    """Lockstep server with the step triple compiled ONCE and reused
    across groups (same steady-state compile budget as the engine)."""
    from repro.runtime import serve as serve_rt

    scfg = serve_rt.ServeConfig(capacity=capacity)
    prefill = jax.jit(serve_rt.make_prefill(cfg, scfg))
    dec_sel = jax.jit(serve_rt.make_decode_step(cfg, scfg, do_select=True))
    dec_reuse = jax.jit(serve_rt.make_decode_step(cfg, scfg,
                                                  do_select=False))
    w = max(cfg.h2eal.share_window, 1)

    def serve(requests, *, max_batch, pad_to):
        t0 = time.time()
        useful = 0
        steps = 0
        for i in range(0, len(requests), max_batch):
            group = requests[i:i + max_batch]
            gen = max(r.max_new for r in group)
            prompts = np.zeros((max_batch, pad_to), np.int32)
            for j, r in enumerate(group):
                prompts[j, :len(r.prompt)] = r.prompt
                prompts[j, len(r.prompt):] = r.prompt[-1]  # repeat-pad
            logits, state = prefill(params, jnp.asarray(prompts))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for s in range(gen):
                fn = dec_sel if (s % w == 0) else dec_reuse
                logits, state = fn(params, state, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(logits)
            useful += sum(r.max_new for r in group)
            steps += gen
        dt = time.time() - t0
        return {"useful_tokens": useful, "decode_steps": steps,
                "wall_s": dt, "tokens_per_s": useful / dt}

    return serve


def run_engine(cfg, params, requests, *, max_batch, capacity, buckets,
               reps=1, layout="default", admission="fifo", attn_impl="ref",
               prefill_chunk=None, hot_pages=None, spec_tokens=None,
               draft="ngram", sampling=None, rebalance="off",
               warm_requests=None, decode_window=None):
    from repro.serving import Engine, Request

    eng = Engine(cfg, params, max_batch=max_batch, capacity=capacity,
                 prompt_buckets=buckets, layout=layout, admission=admission,
                 impl=attn_impl, prefill_chunk=prefill_chunk,
                 hot_pages=hot_pages, spec_tokens=spec_tokens, draft=draft,
                 rebalance=rebalance, decode_window=decode_window)
    # sampling=(temperature, top_p) stamps every measured request; the
    # per-request RNG key is owned by (seed, uid), so the same request
    # list produces the same stochastic trace on ANY engine configuration
    # (the losslessness invariant the spec rows assert)
    temp, topp = sampling if sampling else (0.0, 1.0)

    def stamp(rs):
        return [dataclass_copy(r, temperature=temp, top_p=topp)
                for r in rs]

    if warm_requests is not None:
        # replay a full workload as warmup (uids offset out of the
        # measured range): the rebalance rows need a warmup that
        # actually MIGRATES, or the migrate jit would compile inside
        # the measured phase and trip the no-recompile check
        warm = [dataclass_copy(r, uid=10_000 + r.uid, temperature=temp,
                               top_p=topp) for r in warm_requests]
    else:
        # warmup: touch every prompt bucket and both decode variants
        warm = [Request(uid=10_000 + i, prompt=np.zeros((b,), np.int32),
                        max_new=cfg.h2eal.share_window + 2,
                        temperature=temp, top_p=topp)
                for i, b in enumerate(buckets)]
    eng.run(warm)
    warm_sizes = eng.jit_cache_sizes()

    best = None
    for _ in range(max(reps, 1)):
        eng.reset_metrics()
        t0 = time.time()
        completions = eng.run(stamp(requests))
        dt = time.time() - t0
        if best is None or dt < best[0]:
            best = (dt, completions, dataclass_copy(eng.stats))
    dt, completions, s = best
    sizes = eng.jit_cache_sizes()
    recompiled = any(sizes[k] != warm_sizes[k] for k in sizes
                     if sizes[k] >= 0)
    useful = sum(len(c.tokens) for c in completions.values())
    out = {"useful_tokens": useful, "decode_steps": s.decode_steps,
           "wall_s": dt, "tokens_per_s": useful / dt,
           "steps_per_s": s.decode_steps / dt,
           "tokens_per_step": useful / max(s.decode_steps, 1),
           # dispatch accounting (PR 10): decode_steps stops doubling as
           # the dispatch count once windows fuse — report the logical
           # step rate and the directly-observable dispatch reduction
           "engine_steps": s.engine_steps,
           "engine_steps_per_s": s.engine_steps / dt,
           "dispatches": s.dispatches,
           "steps_per_dispatch": s.decode_steps / max(s.dispatches, 1),
           "occupancy": s.occupancy, "recompiled_after_warmup": recompiled,
           "jit_cache": sizes,
           "tokens": {uid: list(c.tokens)
                      for uid, c in completions.items()}}
    if decode_window:
        out.update({
            "decode_window": decode_window,
            "fused_windows": s.fused_windows,
            "fused_steps": s.fused_steps,
        })
    if sampling:
        out["sampling"] = {"temperature": temp, "top_p": topp}
    if spec_tokens:
        out.update({
            "spec_tokens": spec_tokens,
            "draft": getattr(eng.draft, "name", str(draft)),
            "spec_steps": s.spec_steps,
            "spec_drafted": s.spec_drafted,
            "spec_accepted": s.spec_accepted,
            "mean_accepted_len": s.mean_accepted_len,
        })
    if hot_pages is not None:
        out.update({
            "hot_pages": hot_pages,
            "tier_hits": s.tier_hits, "tier_misses": s.tier_misses,
            "tier_spills": s.tier_spills, "tier_fills": s.tier_fills,
            "tier_prefetch": s.tier_prefetch,
            "tier_hit_rate": s.tier_hit_rate,
            # batched-transfer accounting (PR 10): one batched fill +
            # one batched spill per refresh plan
            "tier_fill_batches": s.tier_fill_batches,
            "tier_spill_batches": s.tier_spill_batches,
            "tier_gather_batches": s.tier_gather_batches,
            "tier_batch_pages_max": s.tier_batch_pages_max,
            "tier_fill_batch_mean": s.tier_fill_batch_mean,
            "tier_spill_batch_mean": s.tier_spill_batch_mean,
        })
    if rebalance != "off":
        out.update({
            "rebalance": rebalance,
            "rebalance_checks": s.rebalance_checks,
            "rebalances": s.rebalances,
            "migrations": s.migrations,
            "migrated_tokens": s.migrated_tokens,
            "load_imbalance_pre": s.imbalance_pre,
            "load_imbalance_post": s.imbalance_post,
        })
    return out


def dataclass_copy(x, **changes):
    import dataclasses
    return dataclasses.replace(x, **changes)


def run_latency(cfg, params, *, requests, max_batch, capacity, buckets,
                gen_min, gen_max, seed, layout="default", admission="fifo",
                prefill_chunk=None, arrival_rate=0.5, long_every=3,
                long_len=None):
    """Bursty-arrival latency run: p50/p99 time-to-first-token and
    inter-token latency under Poisson arrivals with periodic max-bucket
    long prompts (the head-of-line blocking scenario chunked prefill
    targets).

    Requests arrive by a seeded Poisson process (``arrival_rate``
    requests per engine step, exponential inter-arrivals); every
    ``long_every``-th request is a max-bucket prompt, the rest draw from
    the smaller buckets. The engine is driven step-by-step with a device
    sync per step so the per-step timestamps are honest — this is a
    latency harness, not a throughput number (the sync serializes
    dispatch). TTFT = first-token wall time minus submit wall time; ITL
    = wall time between a request's consecutive tokens. With
    prefill-then-pack admission the whole prompt prefills inside one
    loop iteration, so a long arrival stalls every concurrent decode
    (the ITL tail); chunked prefill bounds the stall by one chunk.
    """
    from repro.launch.serve import make_ragged_requests
    from repro.serving import Engine, Request

    # the long-prompt bucket must dwarf a decode step for the
    # head-of-line stall to be visible above dispatch noise
    long_len = long_len or 8 * max(buckets)
    capacity = max(capacity, long_len + gen_max + cfg.h2eal.page_size)
    all_buckets = sorted(set(buckets) | {long_len})
    eng = Engine(cfg, params, max_batch=max_batch, capacity=capacity,
                 prompt_buckets=all_buckets, layout=layout,
                 admission=admission, prefill_chunk=prefill_chunk)
    warm = [Request(uid=10_000 + i, prompt=np.zeros((b,), np.int32),
                    max_new=cfg.h2eal.share_window + 2)
            for i, b in enumerate(all_buckets)]
    eng.run(warm)
    warm_sizes = eng.jit_cache_sizes()
    eng.reset_metrics()

    rng = np.random.default_rng(seed)
    reqs = make_ragged_requests(cfg, n=requests, prompt_buckets=buckets,
                                gen_min=gen_min, gen_max=gen_max, seed=seed)
    for r in reqs[long_every - 1::long_every]:   # bursty long prompts
        r.prompt = rng.integers(0, cfg.vocab_size,
                                size=(long_len,)).astype(np.int32)
    arrive = np.cumsum(rng.exponential(1.0 / arrival_rate, size=requests))
    pending = list(zip(arrive, reqs))

    t0 = time.time()
    times = [t0]                 # times[k] = wall clock after engine step k
    submit_t = {}
    while pending or eng.busy():
        step_no = eng.stats.engine_steps
        while pending and pending[0][0] <= step_no:
            _, r = pending.pop(0)
            submit_t[r.uid] = time.time()
            eng.submit(r)
        if not eng.busy():
            if not pending:
                break
            _, r = pending.pop(0)    # idle: fast-forward the arrival clock
            submit_t[r.uid] = time.time()
            eng.submit(r)
        if eng.poll():
            eng.sync()
            times.append(time.time())  # times[k] = wall after engine step k
    eng.finalize()

    ttft, itl = [], []
    for comp in eng.completions.values():
        if comp.uid not in submit_t:
            continue
        t_first = times[min(comp.first_token_step, len(times) - 1)]
        ttft.append(t_first - submit_t[comp.uid])
        prev = t_first
        for es in eng.token_engine_steps(comp):
            t_tok = times[min(es, len(times) - 1)]
            itl.append(t_tok - prev)
            prev = t_tok
    # the structural no-head-of-line claim, at step granularity (exact on
    # any host, unlike the wall-clock percentiles which are dispatch-noise
    # bound on a CPU toy config): tokens OTHER slots emitted between a
    # long request's admission and its first token. Prefill-then-pack is
    # an atomic admission — always 0; chunked admission keeps decoding.
    during = []
    longs = [c for c in eng.completions.values()
             if c.uid in submit_t and c.prompt_len == long_len]
    for lc in longs:
        n = sum(
            1
            for c in eng.completions.values()
            if c.uid != lc.uid and c.uid in submit_t
            for es in eng.token_engine_steps(c)
            if lc.admitted_engine_step < es < lc.first_token_step)
        during.append(n)
    pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
    s = eng.stats
    recompiled = any(a != b for a, b in zip(eng.jit_cache_sizes().values(),
                                            warm_sizes.values()))
    return {
        "useful_tokens": s.tokens_out, "decode_steps": s.decode_steps,
        "engine_steps": s.engine_steps, "prefill_chunks": s.prefill_chunks,
        "admissions": s.admissions,
        "wall_s": times[-1] - t0,
        "tokens_per_s": s.tokens_out / max(times[-1] - t0, 1e-9),
        "tokens_per_step": s.tokens_out / max(s.decode_steps, 1),
        "occupancy": s.occupancy,
        "recompiled_after_warmup": recompiled,
        "jit_cache": eng.jit_cache_sizes(),
        "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
        "itl_p50_s": pct(itl, 50), "itl_p99_s": pct(itl, 99),
        "long_len": long_len,
        "decode_tokens_during_long_prefill":
            float(np.mean(during)) if during else 0.0,
    }


def _row(mode, layout, impl, r, *, lock=None, extra=None):
    """One machine-readable benchmark row (the --json payload unit)."""
    row = {"mode": mode, "layout": layout, "impl": impl,
           "tokens_per_s": r["tokens_per_s"],
           "tokens_per_step": r["tokens_per_step"],
           "decode_steps": r["decode_steps"],
           "useful_tokens": r["useful_tokens"],
           "wall_s": r["wall_s"]}
    if "occupancy" in r:
        row["occupancy"] = r["occupancy"]
    if "recompiled_after_warmup" in r:
        row["recompiled_after_warmup"] = r["recompiled_after_warmup"]
        row["jit_cache"] = r["jit_cache"]
    # split-rate + sampling/speculation fields (PR 8): tokens_per_s and
    # steps_per_s coincide per slot without speculation; a verify step
    # emits up to k tokens per slot, so spec rows report both
    for key in ("steps_per_s", "sampling", "spec_tokens", "draft",
                "spec_steps", "spec_drafted", "spec_accepted",
                "mean_accepted_len",
                # dispatch accounting + fused decode windows (PR 10)
                "engine_steps", "engine_steps_per_s", "dispatches",
                "steps_per_dispatch", "decode_window", "fused_windows",
                "fused_steps"):
        if key in r:
            row[key] = r[key]
    if lock is not None:
        row["speedup_vs_lockstep"] = r["tokens_per_s"] / lock["tokens_per_s"]
    if extra:
        row.update(extra)
    return row


def run(csv: bool = True, *, requests=24, max_batch=4, gen_min=2,
        gen_max=40, seed=0, reps=3, layout="default", layouts=None,
        attn_impl=None, json_path=None, prefill_chunk=None,
        arrival="batch", arrival_rate=0.5, tiered_hot_pages=None,
        spec_tokens=None, sampling=None, rebalance=False,
        decode_window=None):
    """Lockstep vs ragged at equal token budget, per layout (x impl).

    ``layouts`` is an iterable of core/layouts registry names (default:
    just the default layout; the deprecated single ``layout=`` alias is
    folded in). ``prefill_chunk=N`` adds, per layout, a chunked-prefill
    engine row (admission interleaved with decode, N tokens/step) next
    to the prefill-then-pack row, with a ``tokens_match_packed`` check.
    ``arrival="poisson"`` additionally runs the bursty-arrival LATENCY
    harness (``run_latency``) per layout — packed vs chunked p50/p99
    TTFT and inter-token latency rows. ``json_path`` writes the
    machine-readable row list (tok/s per layout x impl x admission mode,
    occupancy, recompile flags, latency percentiles) — the
    BENCH_serve.json artifact scripts/ci.sh smokes.

    ``spec_tokens=k`` adds, per layout, a speculative-decode engine row
    (self-drafted ngram prompt-lookup, one chunked verify forward per
    step) with a ``tokens_match_nonspec`` flag against the non-spec row
    — the coupled rejection sampler makes the trace EXACTLY the
    non-speculative one, greedy or stochastic — plus the dedicated
    ngram-friendly workload pair (constant-token prompts, widened share
    window so the selection-refresh boundary doesn't clamp acceptance)
    that carries the speculative >= non-spec tokens/s ratio gate.
    ``sampling=(temperature, top_p)`` adds stochastic rows: a sampled
    non-spec row per layout and (with ``spec_tokens``) a sampled
    speculative row token-matched against it.

    ``rebalance=True`` adds the rebalancing row pair: a CHURN workload
    (ragged prompts AND ragged budgets, so retirements leave the batch
    skewed) served twice — Engine(rebalance="off") vs "retire" — with a
    ``tokens_match_norebalance`` exact check, the migration counters,
    and ``load_imbalance_pre``/``load_imbalance_post`` (the cost-model
    bank imbalance at each rebalance check, before/after the applied
    plan — the strict-reduction gate in bench_bands.json). Both engines
    warm up on a replay of the same workload so the migrate jit
    compiles before the measured phase.

    ``decode_window=w`` adds the fused decode-window row trio on a
    widened share window (reduced() uses share_window=2, leaving one
    reuse step per window — too narrow for fusion to matter): its OWN
    lockstep baseline on the widened config, a per-step engine row
    (``decode_window=None``) and the fused row
    (``Engine(decode_window=w)``) — with a ``tokens_match_unfused``
    exact check, the dispatch counters, and ``speedup_vs_perstep`` (the
    fused >= per-step tokens/s ratio gate in bench_bands.json).
    """
    from repro.configs import get_arch, reduced
    from repro.core import layouts as layoutlib
    from repro.models import model as M

    names = [layoutlib.resolve_layout(n)
             for n in (layouts if layouts else [layout])]

    cfg = reduced(get_arch("smollm-360m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    buckets = [24, 48]
    capacity = max(buckets) + gen_max + cfg.h2eal.page_size
    reqs = build_requests(cfg, n=requests, buckets=buckets,
                          gen_min=gen_min, gen_max=gen_max, seed=seed)

    # warm the lockstep jits (one group); measure best-of-reps (wall time
    # on a contended CPU is noisy; the step counts are deterministic)
    lockstep = make_lockstep_runner(cfg, params, capacity=capacity)
    lockstep(reqs[:max_batch], max_batch=max_batch, pad_to=max(buckets))
    lock = min((lockstep(reqs, max_batch=max_batch, pad_to=max(buckets))
                for _ in range(max(reps, 1))), key=lambda r: r["wall_s"])
    lock["tokens_per_step"] = (lock["useful_tokens"]
                               / max(lock["decode_steps"], 1))
    rows = [_row("lockstep", "default", "ref", lock)]
    out = {"lockstep": lock, "layouts": {}}
    if csv:
        print(f"serve_throughput,devices,{len(jax.devices())},"
              f"lockstep_tok_s,{lock['tokens_per_s']:.2f},steps,"
              f"{lock['decode_steps']},tok_per_step,"
              f"{lock['tokens_per_step']:.2f}")

    for name in names:
        admission = ("balanced" if layoutlib.get_layout(name).shards_pages
                     else "fifo")
        rag = run_engine(cfg, params, reqs, max_batch=max_batch,
                         capacity=capacity, buckets=buckets, reps=reps,
                         layout=name, admission=admission)
        ratio = rag["tokens_per_s"] / lock["tokens_per_s"]
        step_ratio = rag["tokens_per_step"] / lock["tokens_per_step"]
        rows.append(_row("ragged", name, "ref", rag, lock=lock))
        out["layouts"][name] = {"ragged": rag, "speedup": ratio,
                                "step_reduction": step_ratio}
        if csv:
            print(f"serve_throughput,layout,{name}")
            print(f"serve_throughput,ragged_tok_s,"
                  f"{rag['tokens_per_s']:.2f},steps,"
                  f"{rag['decode_steps']},tok_per_step,"
                  f"{rag['tokens_per_step']:.2f},occupancy,"
                  f"{rag['occupancy']:.2f}")
            print(f"serve_throughput,wall_speedup,{ratio:.2f},"
                  f"per_step_throughput_gain,{step_ratio:.2f}")
            print(f"serve_throughput,recompiled_after_warmup,"
                  f"{rag['recompiled_after_warmup']},jit_cache,"
                  f"\"{rag['jit_cache']}\"")
        if prefill_chunk:
            # chunked-prefill row: same requests/admission, the prompt KV
            # streams into the sharded slots chunk-by-chunk instead of
            # prefill-then-pack; tokens must match the packed row (off
            # argmax ties, EXPERIMENTS.md)
            chk = run_engine(cfg, params, reqs, max_batch=max_batch,
                             capacity=capacity, buckets=buckets, reps=reps,
                             layout=name, admission=admission,
                             prefill_chunk=prefill_chunk)
            match = chk["tokens"] == rag["tokens"]
            rows.append(_row("ragged", name, "ref", chk, lock=lock,
                             extra={"prefill_chunk": prefill_chunk,
                                    "tokens_match_packed": match}))
            out["layouts"][name]["chunked"] = chk
            out["layouts"][name]["chunked_tokens_match_packed"] = match
            if csv:
                print(f"serve_throughput,prefill_chunk,{prefill_chunk},"
                      f"tok_s,{chk['tokens_per_s']:.2f},"
                      f"tokens_match_packed,{match},"
                      f"recompiled_after_warmup,"
                      f"{chk['recompiled_after_warmup']}")
        samp = None
        if sampling:
            # stochastic non-spec row: same requests, per-request RNG
            # keys (seed, uid) — the reference trace the sampled
            # speculative row must reproduce exactly
            samp = run_engine(cfg, params, reqs, max_batch=max_batch,
                              capacity=capacity, buckets=buckets, reps=reps,
                              layout=name, admission=admission,
                              sampling=sampling)
            rows.append(_row("ragged", name, "ref", samp, lock=lock))
            out["layouts"][name]["sampled"] = samp
            if csv:
                print(f"serve_throughput,sampling,"
                      f"{sampling[0]},{sampling[1]},tok_s,"
                      f"{samp['tokens_per_s']:.2f},recompiled_after_warmup,"
                      f"{samp['recompiled_after_warmup']}")
        if spec_tokens:
            # speculative rows: the coupled rejection sampler emits the
            # EXACT non-speculative trace (greedy = temp-0 special case),
            # so both flags below are exact-match gates, not heuristics
            for lbl, smp, ref in ((("greedy"), None, rag),
                                  (("sampled"), sampling, samp)):
                if lbl == "sampled" and not sampling:
                    continue
                spec_r = run_engine(cfg, params, reqs, max_batch=max_batch,
                                    capacity=capacity, buckets=buckets,
                                    reps=reps, layout=name,
                                    admission=admission,
                                    spec_tokens=spec_tokens, sampling=smp)
                match = spec_r["tokens"] == ref["tokens"]
                rows.append(_row("ragged", name, "ref", spec_r, lock=lock,
                                 extra={"tokens_match_nonspec": match}))
                out["layouts"][name][f"spec_{lbl}"] = spec_r
                out["layouts"][name][f"spec_{lbl}_match"] = match
                if csv:
                    print(f"serve_throughput,spec_tokens,{spec_tokens},"
                          f"{lbl},tok_s,{spec_r['tokens_per_s']:.2f},"
                          f"steps_per_s,{spec_r['steps_per_s']:.2f},"
                          f"mean_accepted_len,"
                          f"{spec_r['mean_accepted_len']:.2f},"
                          f"tokens_match_nonspec,{match},"
                          f"recompiled_after_warmup,"
                          f"{spec_r['recompiled_after_warmup']}")
        if arrival == "poisson":
            for label, pc in (("packed", None), ("chunked", prefill_chunk)):
                if label == "chunked" and not prefill_chunk:
                    continue
                lat = run_latency(
                    cfg, params, requests=requests, max_batch=max_batch,
                    capacity=capacity, buckets=buckets, gen_min=gen_min,
                    gen_max=gen_max, seed=seed, layout=name,
                    admission=admission, prefill_chunk=pc,
                    arrival_rate=arrival_rate)
                rows.append(_row("poisson", name, "ref", lat, extra={
                    "prefill_chunk": pc or 0, "admission_mode": label,
                    "arrival_rate": arrival_rate,
                    "long_len": lat["long_len"],
                    "ttft_p50_s": lat["ttft_p50_s"],
                    "ttft_p99_s": lat["ttft_p99_s"],
                    "itl_p50_s": lat["itl_p50_s"],
                    "itl_p99_s": lat["itl_p99_s"],
                    "decode_tokens_during_long_prefill":
                        lat["decode_tokens_during_long_prefill"]}))
                out["layouts"][name][f"poisson_{label}"] = lat
                if csv:
                    print(f"serve_throughput,poisson,{label},layout,{name},"
                          f"ttft_p50_ms,{lat['ttft_p50_s']*1e3:.1f},"
                          f"ttft_p99_ms,{lat['ttft_p99_s']*1e3:.1f},"
                          f"itl_p50_ms,{lat['itl_p50_s']*1e3:.1f},"
                          f"itl_p99_ms,{lat['itl_p99_s']*1e3:.1f},"
                          f"decode_tok_during_long_prefill,"
                          f"{lat['decode_tokens_during_long_prefill']:.1f}")
        if attn_impl == "pallas":
            # ref-vs-pallas comparison row: same requests, same admission
            # trace, only the attention kernel impl differs
            # (EXPERIMENTS.md).
            pal = run_engine(cfg, params, reqs, max_batch=max_batch,
                             capacity=capacity, buckets=buckets, reps=reps,
                             layout=name, admission=admission,
                             attn_impl="pallas")
            match = pal["tokens"] == rag["tokens"]
            impl_ratio = pal["tokens_per_s"] / rag["tokens_per_s"]
            rows.append(_row("ragged", name, "pallas", pal, lock=lock,
                             extra={"tokens_match_ref": match}))
            if csv:
                print(f"serve_throughput,attn_impl,pallas,tok_s,"
                      f"{pal['tokens_per_s']:.2f},vs_ref,{impl_ratio:.2f},"
                      f"tokens_match_ref,{match},recompiled_after_warmup,"
                      f"{pal['recompiled_after_warmup']}")
            out["layouts"][name]["pallas"] = pal
            out["layouts"][name]["pallas_tokens_match_ref"] = match

    if tiered_hot_pages:
        # tiered hot/cold residency rows: a DEEPER workload (long
        # prompts, page table >= 2x oversubscribed vs the hot budget) so
        # the spill/prefetch machinery actually runs, served twice —
        # all-resident oracle vs Engine(hot_pages=N) — with a
        # token-exactness flag and the modeled far-bank traffic
        # (runtime.perfmodel byte counts through the hbsim NoC link)
        from repro.hbsim import sim as hbsim

        t_buckets = [128]
        t_gen = 12
        t_cap = 160
        t_reqs = build_requests(cfg, n=8, buckets=t_buckets,
                                gen_min=t_gen, gen_max=t_gen, seed=seed)
        res = run_engine(cfg, params, t_reqs, max_batch=2,
                         capacity=t_cap, buckets=t_buckets, reps=reps)
        tier = run_engine(cfg, params, t_reqs, max_batch=2,
                         capacity=t_cap, buckets=t_buckets, reps=reps,
                         hot_pages=tiered_hot_pages)
        match = tier["tokens"] == res["tokens"]
        p = cfg.h2eal.page_size
        slot_pages = -(-(max(t_buckets) + t_gen) // p)
        oversub = slot_pages / tiered_hot_pages
        modeled = hbsim.tiered_serving_overhead(
            cfg, fills=tier["tier_fills"], spills=tier["tier_spills"],
            prefetch=tier["tier_prefetch"],
            decode_steps=tier["decode_steps"])
        rows.append(_row("ragged", "default", "ref", res,
                         extra={"tier": "resident",
                                "prompt_len": max(t_buckets)}))
        rows.append(_row("ragged", "default", "ref", tier, extra={
            "tier": "tiered", "hot_pages": tiered_hot_pages,
            "oversubscription": oversub,
            "tokens_match_resident": match,
            "tier_hits": tier["tier_hits"],
            "tier_misses": tier["tier_misses"],
            "tier_spills": tier["tier_spills"],
            "tier_fills": tier["tier_fills"],
            "tier_prefetch": tier["tier_prefetch"],
            "tier_hit_rate": tier["tier_hit_rate"],
            "far_bank_modeled": modeled}))
        out["tiered"] = {"resident": res, "tiered": tier,
                         "tokens_match_resident": match,
                         "oversubscription": oversub,
                         "far_bank_modeled": modeled}
        if csv:
            print(f"serve_throughput,tiered,hot_pages,{tiered_hot_pages},"
                  f"oversubscription,{oversub:.2f},tok_s,"
                  f"{tier['tokens_per_s']:.2f},resident_tok_s,"
                  f"{res['tokens_per_s']:.2f},hit_rate,"
                  f"{tier['tier_hit_rate']:.3f},spills,"
                  f"{tier['tier_spills']},fills,{tier['tier_fills']},"
                  f"prefetch,{tier['tier_prefetch']},"
                  f"tokens_match_resident,{match}")

    if spec_tokens:
        # the throughput-gate workload: speculation only pays when the
        # draft is usually right AND acceptance may run several tokens
        # before a selection refresh, so this pair is constructed to sit
        # in that regime. Constant-token prompts + an init seed whose
        # greedy continuation locks into a period-1 cycle (PRNGKey(3);
        # seed 0's continuation breaks its runs every ~5 tokens, capping
        # prompt-lookup acceptance near 2) make the suffix-n-gram draft
        # usually right, and a share window widened to 2k keeps the
        # selection-refresh boundary from clamping max_emit below k.
        # Served twice — non-spec vs Engine(spec_tokens=k) — this pair
        # carries the `speculative >= non-spec tokens/s` ratio gate in
        # bench_bands.json; the per-layout rows above measure the
        # ngram-hostile random workload and are NOT ratio-gated.
        import dataclasses

        from repro.serving import Request

        s_cfg = dataclasses.replace(
            cfg, h2eal=dataclasses.replace(cfg.h2eal,
                                           share_window=2 * spec_tokens))
        s_params = M.init_params(cfg, jax.random.PRNGKey(3))
        s_gen = 48
        s_cap = max(buckets) + s_gen + cfg.h2eal.page_size
        s_reqs = [Request(uid=i,
                          prompt=np.full((buckets[i % 2],), 7, np.int32),
                          max_new=s_gen)
                  for i in range(8)]
        # batch/reps pinned (not the CLI smoke flags): max_batch=1 is
        # the latency-bound regime speculation targets — per-step fixed
        # dispatch cost amortizes over accepted tokens, whereas at
        # larger batches this host is compute-saturated and the k-query
        # verify forward costs its full flops (ratio ~0.9 at B=4,
        # ~1.3 at B=1 with the same 3.36 acceptance); reps >= 2 because
        # a 1-rep run is noise-bound on a contended CI host
        s_mb, s_reps = 1, max(reps, 2)
        base_n = run_engine(s_cfg, s_params, s_reqs, max_batch=s_mb,
                            capacity=s_cap, buckets=buckets, reps=s_reps)
        spec_n = run_engine(s_cfg, s_params, s_reqs, max_batch=s_mb,
                            capacity=s_cap, buckets=buckets, reps=s_reps,
                            spec_tokens=spec_tokens)
        match = spec_n["tokens"] == base_n["tokens"]
        ratio = spec_n["tokens_per_s"] / base_n["tokens_per_s"]
        rows.append(_row("ragged", "default", "ref", base_n,
                         extra={"workload": "ngram"}))
        rows.append(_row("ragged", "default", "ref", spec_n,
                         extra={"workload": "ngram",
                                "tokens_match_nonspec": match,
                                "speedup_vs_nonspec": ratio}))
        out["spec_ngram"] = {"nonspec": base_n, "spec": spec_n,
                             "tokens_match_nonspec": match,
                             "speedup_vs_nonspec": ratio}
        if csv:
            print(f"serve_throughput,spec_ngram,k,{spec_tokens},"
                  f"share_window,{s_cfg.h2eal.share_window},"
                  f"mean_accepted_len,{spec_n['mean_accepted_len']:.2f},"
                  f"tok_s,{spec_n['tokens_per_s']:.2f},nonspec_tok_s,"
                  f"{base_n['tokens_per_s']:.2f},speedup,{ratio:.2f},"
                  f"tokens_match_nonspec,{match}")

    if decode_window:
        # fused decode-window row trio (PR 10): the windows only pay
        # when a share window holds several reuse steps, so this pair
        # runs on a widened share window (reduced() uses 2 — a single
        # reuse step per window) with generation lengths spanning
        # several windows. The per-step row is BOTH the token-exactness
        # reference and the tokens/s denominator; the widened config
        # gets its own lockstep baseline so speedup_vs_lockstep stays
        # honest.
        import dataclasses

        f_w = 8
        f_cfg = dataclasses.replace(
            cfg, h2eal=dataclasses.replace(cfg.h2eal, share_window=f_w))
        # decode-heavy shape: short prompts (smallest bucket only) and
        # generations spanning 3-6 windows, so the dispatch savings the
        # fusion buys are measured against decode wall, not prefill
        f_buckets = [min(buckets)]
        f_gen_min, f_gen_max = 3 * f_w, 6 * f_w
        f_cap = max(f_buckets) + f_gen_max + cfg.h2eal.page_size
        f_reqs = build_requests(cfg, n=12, buckets=f_buckets,
                                gen_min=f_gen_min, gen_max=f_gen_max,
                                seed=seed)
        f_lockstep = make_lockstep_runner(f_cfg, params, capacity=f_cap)
        f_lockstep(f_reqs[:max_batch], max_batch=max_batch,
                   pad_to=max(f_buckets))
        f_lock = min((f_lockstep(f_reqs, max_batch=max_batch,
                                 pad_to=max(f_buckets))
                      for _ in range(max(reps, 1))),
                     key=lambda r: r["wall_s"])
        f_lock["tokens_per_step"] = (f_lock["useful_tokens"]
                                     / max(f_lock["decode_steps"], 1))
        # best-of-3 wall clocks: the ratio gate in bench_bands.json is
        # exact (not banded), and fused-vs-per-step differ by ~100 ms
        # on the toy config — single-rep scheduler noise could flip it
        f_reps = max(reps, 3)
        base_f = run_engine(f_cfg, params, f_reqs, max_batch=max_batch,
                            capacity=f_cap, buckets=f_buckets, reps=f_reps)
        fus = run_engine(f_cfg, params, f_reqs, max_batch=max_batch,
                         capacity=f_cap, buckets=f_buckets, reps=f_reps,
                         decode_window=decode_window)
        match = fus["tokens"] == base_f["tokens"]
        ratio = fus["tokens_per_s"] / base_f["tokens_per_s"]
        rows.append(_row("ragged", "default", "ref", base_f, lock=f_lock,
                         extra={"workload": "fusedwin",
                                "share_window": f_w}))
        rows.append(_row("ragged", "default", "ref", fus, lock=f_lock,
                         extra={"workload": "fusedwin",
                                "share_window": f_w,
                                "tokens_match_unfused": match,
                                "speedup_vs_perstep": ratio}))
        out["fused"] = {"perstep": base_f, "fused": fus,
                        "tokens_match_unfused": match,
                        "speedup_vs_perstep": ratio}
        if csv:
            print(f"serve_throughput,fused_window,{decode_window},"
                  f"share_window,{f_w},tok_s,{fus['tokens_per_s']:.2f},"
                  f"perstep_tok_s,{base_f['tokens_per_s']:.2f},"
                  f"speedup_vs_perstep,{ratio:.2f},dispatches,"
                  f"{fus['dispatches']},perstep_dispatches,"
                  f"{base_f['dispatches']},steps_per_dispatch,"
                  f"{fus['steps_per_dispatch']:.2f},fused_windows,"
                  f"{fus['fused_windows']},tokens_match_unfused,{match},"
                  f"recompiled_after_warmup,"
                  f"{fus['recompiled_after_warmup']}")

    if rebalance:
        # rebalancing row pair: the churn workload mixes short/long
        # prompts with short/long budgets at seed-determined positions,
        # so early retirements leave heavy slots clustered in one bank —
        # the drift the retire-triggered planner exists to undo. Served
        # twice (rebalance off vs retire) with identical requests: the
        # trace must match token-for-token (migration moves cache rows
        # verbatim; sampling keys are (seed, uid)-owned), and the mean
        # cost-model bank imbalance at the rebalance checks must drop
        # strictly (the bench_bands.json imbalance gate).
        from repro.hbsim import sim as hbsim

        rb_buckets = [8, 16, 24]
        rb_gen_max = 19
        rb_cap = max(rb_buckets) + rb_gen_max + cfg.h2eal.page_size
        rb_reqs = build_requests(cfg, n=12, buckets=rb_buckets,
                                 gen_min=3, gen_max=rb_gen_max, seed=seed)
        base_rb = run_engine(cfg, params, rb_reqs, max_batch=4,
                             capacity=rb_cap, buckets=rb_buckets,
                             reps=reps, warm_requests=rb_reqs)
        reb = run_engine(cfg, params, rb_reqs, max_batch=4,
                         capacity=rb_cap, buckets=rb_buckets, reps=reps,
                         rebalance="retire", warm_requests=rb_reqs)
        match = reb["tokens"] == base_rb["tokens"]
        modeled = hbsim.rebalance_overhead(
            cfg, migrations=reb["migrations"],
            migrated_tokens=reb["migrated_tokens"],
            decode_steps=reb["decode_steps"])
        rows.append(_row("ragged", "default", "ref", base_rb,
                         extra={"workload": "churn"}))
        rows.append(_row("ragged", "default", "ref", reb, extra={
            "workload": "churn+rb", "rebalance": "retire",
            "tokens_match_norebalance": match,
            "migrations": reb["migrations"],
            "rebalances": reb["rebalances"],
            "rebalance_checks": reb["rebalance_checks"],
            "load_imbalance_pre": reb["load_imbalance_pre"],
            "load_imbalance_post": reb["load_imbalance_post"],
            "rebalance_modeled": modeled}))
        out["rebalance"] = {"norebalance": base_rb, "rebalanced": reb,
                            "tokens_match_norebalance": match,
                            "rebalance_modeled": modeled}
        if csv:
            print(f"serve_throughput,rebalance,retire,migrations,"
                  f"{reb['migrations']},applied,{reb['rebalances']},"
                  f"imbalance_pre,{reb['load_imbalance_pre']:.3f},"
                  f"imbalance_post,{reb['load_imbalance_post']:.3f},"
                  f"tok_s,{reb['tokens_per_s']:.2f},norebalance_tok_s,"
                  f"{base_rb['tokens_per_s']:.2f},"
                  f"tokens_match_norebalance,{match},"
                  f"recompiled_after_warmup,"
                  f"{reb['recompiled_after_warmup']}")

    # back-compat single-layout view (deprecated alias, one release)
    first = out["layouts"][names[0]]
    out.update({"ragged": first["ragged"], "speedup": first["speedup"],
                "step_reduction": first["step_reduction"]})
    if "pallas" in first:
        out["pallas"] = first["pallas"]
        out["pallas_tokens_match_ref"] = first["pallas_tokens_match_ref"]

    if json_path:
        import json

        payload = {
            "benchmark": "serve_throughput",
            "devices": len(jax.devices()),
            "config": {"requests": requests, "max_batch": max_batch,
                       "gen_min": gen_min, "gen_max": gen_max,
                       "seed": seed, "reps": reps,
                       "prompt_buckets": buckets, "capacity": capacity},
            "rows": [{k: v for k, v in r.items() if k != "tokens"}
                     for r in rows],
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        if csv:
            print(f"serve_throughput,json,{json_path},rows,{len(rows)}")
    return out


if __name__ == "__main__":
    from repro.core.layouts import available_layouts

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gen-min", type=int, default=2)
    ap.add_argument("--gen-max", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--layout", default="default",
                    help="comma-separated engine serve-cache layouts "
                         f"(registry entries: {', '.join(available_layouts())}; "
                         "page-sharding layouts get balanced admission)")
    ap.add_argument("--attn-impl", choices=["ref", "pallas"], default="ref",
                    help="pallas = add the ref-vs-pallas comparison row "
                         "per layout (Pallas kernels; interpret mode "
                         "off-TPU)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="add a chunked-prefill engine row per layout "
                         "(N prompt tokens per engine step, interleaved "
                         "with decode; 0 = prefill-then-pack only)")
    ap.add_argument("--arrival", choices=["batch", "poisson"],
                    default="batch",
                    help="poisson = bursty-arrival LATENCY rows (p50/p99 "
                         "TTFT + inter-token latency, packed vs chunked; "
                         "per-step device sync, not a throughput number)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="poisson arrivals per engine step")
    ap.add_argument("--tiered-hot-pages", type=int, default=0,
                    help="add the tiered-residency row pair: a deep-"
                         "prompt workload served all-resident and with "
                         "Engine(hot_pages=N) (spill/prefetch through "
                         "the host far store), with hit/miss/spill/"
                         "prefetch counters, a tokens_match_resident "
                         "flag, and the modeled far-bank traffic")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="add speculative-decode rows per layout "
                         "(Engine(spec_tokens=k), ngram prompt-lookup "
                         "draft, tokens_match_nonspec exact check) plus "
                         "the ngram-friendly workload pair carrying the "
                         "spec >= non-spec tokens/s ratio gate; 0 = off")
    ap.add_argument("--sampling", default=None, metavar="TEMP,TOP_P",
                    help="add stochastic-sampling rows per layout "
                         "(per-request RNG keys; with --spec-tokens also "
                         "a sampled speculative row token-matched "
                         "against the sampled non-spec row)")
    ap.add_argument("--rebalance", action="store_true",
                    help="add the rebalancing row pair: a churn workload "
                         "served with Engine(rebalance='off') vs "
                         "'retire' — tokens_match_norebalance exact "
                         "check, migration counters, and the "
                         "load_imbalance_pre/post strict-reduction gate")
    ap.add_argument("--decode-window", type=int, default=0,
                    help="add the fused decode-window row trio on a "
                         "widened share window: own lockstep baseline, "
                         "per-step engine row, and Engine(decode_window"
                         "=w) — tokens_match_unfused exact check, "
                         "dispatch counters, speedup_vs_perstep ratio "
                         "gate; 0 = off")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable row list (tok/s per "
                         "layout x impl x admission mode, occupancy, "
                         "recompile flags, latency percentiles) to PATH, "
                         "e.g. BENCH_serve.json")
    a = ap.parse_args()
    samp = None
    if a.sampling:
        parts = [float(s) for s in a.sampling.split(",")]
        samp = (parts[0], parts[1] if len(parts) > 1 else 1.0)
    run(requests=a.requests, max_batch=a.max_batch, gen_min=a.gen_min,
        gen_max=a.gen_max, seed=a.seed, reps=a.reps,
        layouts=[s.strip() for s in a.layout.split(",") if s.strip()],
        attn_impl=None if a.attn_impl == "ref" else a.attn_impl,
        json_path=a.json, prefill_chunk=a.prefill_chunk or None,
        arrival=a.arrival, arrival_rate=a.arrival_rate,
        tiered_hot_pages=a.tiered_hot_pages or None,
        spec_tokens=a.spec_tokens or None, sampling=samp,
        rebalance=a.rebalance, decode_window=a.decode_window or None)
