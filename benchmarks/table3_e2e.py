"""Table III: end-to-end decode throughput + energy efficiency."""
import dataclasses

from repro.configs import get_arch
from repro.hbsim import e2e_decode

PAPER = {  # (tokens/s, tokens/J)
    ("llama2-7b", 65536, "full"): (127.9, 6.32),
    ("llama2-7b", 262144, "full"): (40.8, 1.90),
    ("llama2-7b", 65536, "h2eal"): (459.5, 24.00),
    ("llama2-7b", 262144, "h2eal"): (430.8, 23.20),
    ("llama3-8b", 65536, "full"): (253.4, 14.69),
    ("llama3-8b", 262144, "full"): (113.1, 6.05),
    ("llama3-8b", 65536, "h2eal"): (482.1, 26.10),
    ("llama3-8b", 262144, "h2eal"): (469.7, 25.83),
}


def run(csv=True):
    rows = []
    for (name, seq, mode), (pt, pe) in PAPER.items():
        cfg = get_arch(name)
        h2 = dataclasses.replace(cfg.h2eal, share_window=4)
        r = e2e_decode(cfg, seq, mode, h2=h2)
        rows.append((name, seq, mode, r["tokens_per_s"], pt,
                     r["tokens_per_j"], pe))
        if csv:
            print(f"table3,{name},{seq},{mode},"
                  f"tok_s,{r['tokens_per_s']:.1f},paper,{pt},"
                  f"tok_j,{r['tokens_per_j']:.2f},paper,{pe}")
    return rows


if __name__ == "__main__":
    run()
