"""Fig 11: latency breakdown before/after balancing (bank idle cycles).

Paper example: LLaMA3-8B, 12k sequence — unbalanced placement leaves
~3613 idle cycles on the streaming-head banks; balancing eliminates them
(2.01x in their example).
"""
import dataclasses

from repro.configs import get_arch
from repro.hbsim import HBConfig, attention_decode


def run(csv=True):
    cfg = get_arch("llama3-8b")
    h2 = dataclasses.replace(cfg.h2eal, share_window=1)
    hb = HBConfig()
    seq = 12 * 1024
    u = attention_decode(cfg, seq, "sparse_unbalanced", hb, h2=h2)
    b = attention_decode(cfg, seq, "h2eal", hb, h2=h2)
    # idle cycles on the fastest bank while the slowest gates the layer
    freq = 400e6
    per_layer_u = u["latency_s"] / len(cfg.attention_layers)
    fastest = min(t for t in u["bank_times"] if t > 0)
    idle_cycles = (per_layer_u - fastest) * freq
    speedup = u["latency_s"] / b["latency_s"]
    if csv:
        print(f"fig11,unbalanced_idle_cycles,{idle_cycles:.0f},paper,3613")
        print(f"fig11,balance_speedup,{speedup:.2f},paper,2.01")
        bt = ",".join(f"{t*1e6:.2f}" for t in sorted(u["bank_times"]))
        print(f"fig11,unbalanced_bank_times_us,{bt}")
        bt = ",".join(f"{t*1e6:.2f}" for t in sorted(b["bank_times"]))
        print(f"fig11,balanced_bank_times_us,{bt}")
    return {"idle_cycles": idle_cycles, "speedup": speedup}


if __name__ == "__main__":
    run()
