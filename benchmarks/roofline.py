"""Roofline table generator: reads dryrun JSON -> markdown for
EXPERIMENTS.md §Roofline."""
import argparse
import json


def fmt(results):
    lines = [
        "| arch | shape | mesh | kind | compute s | memory s | coll s | "
        "dominant | MFLOPs/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - "
                         f"| - | - | - | ERROR | - | {r['error'][:60]} |")
            continue
        rl = r["roofline"]
        ratio = (r["model_flops_global"] / r["hlo_flops_global"]
                 if r.get("hlo_flops_global") else float("nan"))
        terms = {"compute": rl["compute_s"], "memory": rl["memory_s"],
                 "collective": rl["collective_s"]}
        dom = rl["dominant"]
        note = r.get("layout") or ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} "
            f"| {rl['compute_s']:.2e} | {rl['memory_s']:.2e} "
            f"| {rl['collective_s']:.2e} | **{dom}** "
            f"| {ratio:.2f} | {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("json", nargs="+")
    args = ap.parse_args()
    results = []
    for p in args.json:
        with open(p) as f:
            results.extend(json.load(f))
    print(fmt(results))


if __name__ == "__main__":
    main()
