"""Fixed-pool decode with eviction (paper's kv_budget 'memory
consideration'): ample pool == no-eviction semantics; tight pool keeps
sink/local resident and evicts only low-importance middle pages."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import H2ealConfig
from repro.core import cache as cachelib
from repro.core.hybrid_attention import (
    AttnSpec,
    decode_attention,
    decode_attention_pool,
    init_decode_state,
)

KEY = jax.random.PRNGKey(0)
B, HQ, HKV, D = 1, 4, 2, 32
P, SINK, LOCAL = 8, 2, 16


def _spec(budget=0):
    h2 = H2ealConfig(sink=SINK, local=LOCAL, page_size=P, select_budget=32,
                     share_window=1, kv_budget=budget)
    return AttnSpec(n_q=HQ, n_kv=HKV, head_dim=D, h2=h2)


def _fresh_pool(spec, c_pool):
    nr = spec.n_retrieval
    paged = cachelib.make_paged_cache(B, nr, c_pool, P, D,
                                      spec.h2.top_k_pages)
    stream = cachelib.make_stream_cache(B, spec.n_streaming, SINK,
                                        LOCAL + P, D)
    return paged, stream


def test_ample_pool_matches_no_eviction_path():
    """Pool big enough for the whole context ⇒ identical outputs to the
    standard (position-indexed) decode, from-scratch decode of 40 steps."""
    spec = _spec()
    c_pool = 16
    pg_pool, st_pool = _fresh_pool(spec, c_pool)
    pg_std, st_std = _fresh_pool(spec, c_pool)
    length = jnp.int32(0)
    for step in range(40):
        kk = jax.random.split(jax.random.fold_in(KEY, step), 3)
        qn = jax.random.normal(kk[0], (B, HQ, D))
        kn = jax.random.normal(kk[1], (B, HKV, D))
        vn = jax.random.normal(kk[2], (B, HKV, D))
        o1, pg_pool, st_pool = decode_attention_pool(
            spec, qn, kn, vn, pg_pool, st_pool, length, do_select=True)
        o2, pg_std, st_std = decode_attention(
            spec, qn, kn, vn, pg_std, st_std, length, do_select=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=1e-4, err_msg=f"step {step}")
        length = length + 1


def test_tight_pool_protects_sink_and_local():
    """Pool smaller than the context: sink + local pages stay resident;
    outputs stay finite; the pool never exceeds capacity."""
    spec = _spec(budget=64)
    c_pool = 8  # 64 tokens of pool for an 80-token context
    pg, st = _fresh_pool(spec, c_pool)
    length = jnp.int32(0)
    for step in range(80):
        kk = jax.random.split(jax.random.fold_in(KEY, 1000 + step), 3)
        qn = jax.random.normal(kk[0], (B, HQ, D))
        kn = jax.random.normal(kk[1], (B, HKV, D))
        vn = jax.random.normal(kk[2], (B, HKV, D))
        out, pg, st = decode_attention_pool(
            spec, qn, kn, vn, pg, st, length, do_select=True)
        assert np.all(np.isfinite(np.asarray(out))), step
        length = length + 1
    starts = np.asarray(pg.page_start[0, 0])
    live = starts[starts >= 0]
    # capacity respected
    assert len(live) <= c_pool
    # sink page resident
    assert 0 in live
    # the newest (local) pages resident
    ctx = 80
    first_local = max(ctx - LOCAL, 0) // P
    for pos in range(first_local * P, ctx, P):
        assert pos in live, f"local page at {pos} evicted"
    # and something in the middle was genuinely evicted
    all_pages = set(range(0, ctx, P))
    assert len(all_pages - set(live.tolist())) > 0
