"""Tiered hot/cold KV page residency: the token-exactness property suite.

``Engine(hot_pages=N)`` keeps at most ~N pages per slot device-resident,
spills cold pages to the host far store (the simulated HB far bank), and
prefetches the hottest cold pages one share window ahead of each slot's
selection refresh. The exactness argument under test: page selection
depends ONLY on tau metadata + page_start + q — never on page contents —
so a spilled (zeroed) page is still *selected* bit-identically, the
engine detects the cold miss from the readback, fills the page from the
far store, and replays the same select step. A miss is served late,
never approximated and never skipped.

The property sweep drives random spill/prefetch schedules (hot-set
budget), chunk sizes {1, 8, 64}, and slot churn, asserting the tiered
engine's token traces are bit-identical to the all-resident oracle's.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine, Request
from tests._hypothesis_compat import given, settings, st

CAP = 128          # 16 pages of 8 -- enough table for real spill traffic


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_arch("smollm-360m"))
    # shrink the local window and select budget so the selectable
    # (= spillable) section of the page table dominates: at the reduced
    # defaults nearly every page is pinned by sink/local and tiering
    # would be a no-op
    cfg = dataclasses.replace(cfg, h2eal=dataclasses.replace(
        cfg.h2eal, local=8, select_budget=16))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)


def _workload(cfg, seed):
    """Churny 3-request workload over 2 slots: staggered admissions and
    retirements, prompts deep enough to spill (8+ data pages)."""
    return [Request(uid=i, prompt=_prompt(cfg, 64, 100 * seed + i),
                    max_new=6 + 4 * i)
            for i in range(3)]


@pytest.fixture(scope="module")
def oracle(model):
    """All-resident reference traces, computed lazily and cached per
    (chunk, seed) so property examples that share a workload shape pay
    for one oracle run."""
    cfg, params = model
    cache = {}

    def get(chunk, seed):
        key = (chunk, seed)
        if key not in cache:
            eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                         prompt_buckets=[64], prefill_chunk=chunk)
            comps = eng.run(_workload(cfg, seed))
            cache[key] = {u: c.tokens for u, c in comps.items()}
        return cache[key]

    return get


# ---------------------------------------------------------------------------
# The property sweep
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(hot_pages=st.integers(min_value=4, max_value=12),
       chunk=st.sampled_from([1, 8, 64]),
       seed=st.integers(min_value=0, max_value=2))
def test_tiered_token_exact_property(model, oracle, hot_pages, chunk, seed):
    """Any hot-set budget x any prefill chunking x any admission seed:
    the tiered engine's token traces equal the all-resident oracle's,
    bit for bit. Tight budgets force dense spill/miss/fill schedules;
    loose budgets mostly prefetch — exactness must hold across the whole
    policy surface."""
    cfg, params = model
    ref = oracle(chunk, seed)
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[64], prefill_chunk=chunk,
                 hot_pages=hot_pages)
    comps = eng.run(_workload(cfg, seed))
    assert sorted(comps) == sorted(ref)
    for uid in sorted(ref):
        assert comps[uid].tokens == ref[uid], (
            hot_pages, chunk, seed, uid)
    s = eng.stats
    assert s.tier_misses == s.tier_fills   # every miss demand-filled
    assert 0.0 <= s.tier_hit_rate <= 1.0
    if hot_pages <= 6:      # tight budget: spilling must actually happen
        assert s.tier_spills > 0, (hot_pages, chunk, seed)


# ---------------------------------------------------------------------------
# Deterministic anchors
# ---------------------------------------------------------------------------


def test_tiered_spills_prefetch_and_no_recompiles(model, oracle):
    """One tight-budget engine across two differently-shaped workloads:
    spill traffic occurs, the selection hit-rate stays meaningful, and —
    the zero-post-warmup-recompile invariant — the second workload
    reuses every compiled entry including the tier spill/fill jits."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[64], hot_pages=6)
    comps = eng.run(_workload(cfg, 0))
    ref = oracle(None, 0)
    for uid in sorted(ref):
        assert comps[uid].tokens == ref[uid]
    s = eng.stats
    assert s.tier_spills > 0
    assert s.tier_hits + s.tier_misses > 0
    sizes0 = eng.jit_cache_sizes()
    assert {"tier_gather", "tier_spill", "tier_fill"} <= set(sizes0)
    eng.reset_metrics()
    ref1 = oracle(None, 1)
    comps1 = eng.run(_workload(cfg, 1))
    for uid in sorted(ref1):
        assert comps1[uid].tokens == ref1[uid]
    assert eng.jit_cache_sizes() == sizes0   # no post-warmup recompiles


def test_forced_cold_miss_is_served_late_not_skipped(model):
    """Chaos hook: spill EVERY spillable page — including the currently
    selected ones — right before a slot's selection refresh. The refresh
    must detect the cold selection (tier_misses), demand-fill the pages
    (tier_fills), and still emit the all-resident token trace: the miss
    is served late, never silently skipped."""
    cfg, params = model
    req = lambda: Request(uid=0, prompt=_prompt(cfg, 64, 7), max_new=14)
    ref = Engine(cfg, params, max_batch=1, capacity=CAP,
                 prompt_buckets=[64]).run([req()])[0].tokens

    eng = Engine(cfg, params, max_batch=1, capacity=CAP,
                 prompt_buckets=[64], hot_pages=12)
    eng.submit(req())
    eng._admit()
    w = eng.share_window
    forced = 0
    steps = 0
    while eng.busy():
        b = eng.batch
        if (not forced and steps >= 4 and b.active[0]
                and b.phase[0] % w == 0):
            forced = eng.tier_force_spill(0)
        eng.step()
        steps += 1
    assert forced > 0
    eng.finalize()
    assert eng.completions[0].tokens == ref
    s = eng.stats
    assert s.tier_misses > 0, "forced-cold selection never missed"
    assert s.tier_fills == s.tier_misses     # each one demand-filled
    assert s.tier_hit_rate < 1.0
    # the refresh after the repaired selection re-fills the rest of the
    # (ample, hot_pages=12) want-set speculatively — the prefetch path
    assert s.tier_prefetch > 0


def test_tiered_validation(model):
    """Budget bounds fail at construction; hot_pages=None/0 disables
    tiering entirely (no tier jits, no counters)."""
    cfg, params = model
    with pytest.raises(ValueError, match="hot_pages"):
        Engine(cfg, params, max_batch=1, capacity=CAP,
               prompt_buckets=[64], hot_pages=99)
    with pytest.raises(ValueError, match="hot_pages"):
        Engine(cfg, params, max_batch=1, capacity=CAP,
               prompt_buckets=[64], hot_pages=-3)
    eng = Engine(cfg, params, max_batch=1, capacity=CAP,
                 prompt_buckets=[64], hot_pages=None)
    assert eng._tier is None
    assert "tier_fill" not in eng.jit_cache_sizes()
    with pytest.raises(ValueError, match="hot_pages"):
        eng.tier_force_spill(0)
