"""Fused decode windows: one-dispatch share-window scan, bit-exact.

``Engine(decode_window=w)`` runs the reuse steps between two selection
boundaries as ONE dispatched jit — a lax.scan over the per-step decode
body with in-scan sampling (the per-request RNG lanes advance inside
the scan) and device-side retirement: a slot that exhausts its budget
mid-window flips its active lane inside the scan, the host learns at
the window boundary. The correctness contract under test:

  * token traces are BIT-IDENTICAL to the per-step engine across
    decode_window ∈ {1, w, 2w} x {greedy, sampled} x {packed, chunked}
    with ragged budgets forcing mid-window retirement (the engine has
    no EOS token — budget exhaustion IS the retirement path);
  * the fused jits obey the zero-post-warmup-recompile invariant (one
    compiled entry each for ``fused_window`` / ``fused_window_mixed``);
  * speculative decode does not silently degrade: spec_tokens with
    decode_window > 1 is rejected at construction (the fallback to
    per-step dispatch must be explicit — pass decode_window=None);
  * tiered residency composes: residency only changes at selection
    boundaries and reuse steps never touch non-selected pages, so a
    chaos-forced full spill at a boundary is repaired by the select
    miss-replay and the fused windows after it stay bit-exact vs the
    all-resident per-step oracle (docs/serving.md §Fused decode
    windows).

The reduced config pins share_window=2 (a single reuse step between
selects), so the suite widens it to W=4 — fused windows of 3 scan
iterations — via dataclasses.replace.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine, Request
from tests.test_serving import CAP, REPO, _mixed_workload

W = 4              # widened share window (reduced configs pin 2)


def _widen(cfg, w=W):
    return dataclasses.replace(
        cfg, h2eal=dataclasses.replace(cfg.h2eal, share_window=w))


@pytest.fixture(scope="module")
def model():
    cfg = _widen(reduced(get_arch("smollm-360m")))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _workload(cfg, *, sampled=False, seed=2, n=4):
    """The mixed churny workload (ragged max_new=3+2i: budgets straddle
    window boundaries, so slots retire mid-window), optionally with
    stochastic sampling params (RNG keys owned by (seed, uid), so any
    engine configuration must reproduce the same trace)."""
    reqs = _mixed_workload(cfg, seed=seed, n=n)
    if sampled:
        reqs = [dataclasses.replace(r, temperature=0.8, top_p=0.9)
                for r in reqs]
    return reqs


@pytest.fixture(scope="module")
def perstep_trace(model):
    """Per-step-dispatch reference traces, one per (sampled, chunk)."""
    cfg, params = model
    out = {}
    for sampled in (False, True):
        for chunk in (None, 8):
            eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                         prompt_buckets=[16, 24], prefill_chunk=chunk)
            comps = eng.run(_workload(cfg, sampled=sampled))
            out[(sampled, chunk)] = {u: c.tokens for u, c in comps.items()}
    return out


@pytest.mark.parametrize("chunk", [None, 8], ids=["packed", "chunked"])
@pytest.mark.parametrize("sampled", [False, True], ids=["greedy", "sampled"])
@pytest.mark.parametrize("dw", [1, W, 2 * W])
def test_fused_matches_perstep(model, perstep_trace, dw, sampled, chunk):
    """The acceptance matrix: fused token traces equal the per-step
    engine's bit-for-bit, across window sizes (1 = per-step dispatch,
    W = exactly one window per share cadence, 2W = clamped to the
    share-window-1 scan the cadence allows), greedy and stochastic
    sampling, packed and chunked admission, with ragged budgets
    retiring slots mid-window (device-side retirement)."""
    cfg, params = model
    ref = perstep_trace[(sampled, chunk)]
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], prefill_chunk=chunk,
                 decode_window=dw)
    comps = eng.run(_workload(cfg, sampled=sampled))
    assert sorted(comps) == sorted(ref)
    for uid in sorted(ref):
        assert comps[uid].tokens == ref[uid], (dw, sampled, chunk, uid)
    s = eng.stats
    if dw > 1:
        assert s.fused_windows > 0, (dw, sampled, chunk)
        assert s.fused_steps >= s.fused_windows
        # every fused step replaced a would-be per-step dispatch
        assert s.reuse_steps >= s.fused_steps
    else:
        assert s.fused_windows == 0     # decode_window=1 IS per-step


def test_fused_fewer_dispatches_than_perstep(model):
    """The point of the PR, observable in EngineStats: the fused engine
    serves the identical workload in strictly fewer dispatches than the
    per-step engine, and its steps_per_dispatch rises above 1."""
    cfg, params = model
    base = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24])
    base.run(_workload(cfg))
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], decode_window=W)
    eng.run(_workload(cfg))
    assert base.stats.decode_steps == eng.stats.decode_steps
    assert eng.stats.dispatches < base.stats.dispatches, (
        eng.stats.dispatches, base.stats.dispatches)
    assert eng.stats.steps_per_dispatch > base.stats.steps_per_dispatch
    assert base.stats.fused_windows == 0


def test_fused_zero_recompile(model):
    """The fused jits join the zero-post-warmup-recompile invariant:
    exactly one compiled entry for ``fused_window`` (and the mixed
    prefill+decode variant when chunked), stable across a second,
    differently-shaped workload."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], prefill_chunk=8,
                 decode_window=W)
    eng.run(_workload(cfg))
    sizes0 = eng.jit_cache_sizes()
    assert sizes0["fused_window"] in (-1, 1), sizes0
    assert sizes0["fused_window_mixed"] in (-1, 1), sizes0
    eng.reset_metrics()
    eng.run(_workload(cfg, sampled=True, seed=11, n=3))
    assert eng.jit_cache_sizes() == sizes0
    # a per-step engine never builds the fused entries at all
    base = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24])
    assert "fused_window" not in base.jit_cache_sizes()


def test_fused_construction_validation(model):
    """decode_window is validated at construction: non-positive windows
    are rejected, and speculative decode must opt INTO per-step dispatch
    explicitly (decode_window=None) rather than silently degrading."""
    cfg, params = model
    kw = dict(max_batch=2, capacity=CAP, prompt_buckets=[16, 24])
    with pytest.raises(ValueError, match="decode_window"):
        Engine(cfg, params, decode_window=0, **kw)
    with pytest.raises(ValueError, match="per-step dispatch"):
        Engine(cfg, params, decode_window=W, spec_tokens=4, **kw)
    # the documented fallback spelling constructs (and stays per-step)
    eng = Engine(cfg, params, decode_window=None, spec_tokens=4, **kw)
    assert eng.decode_window == 1
    assert "fused_window" not in eng.jit_cache_sizes()


# ---------------------------------------------------------------------------
# Tiered residency inside fused windows (the ISSUE-10 tier bugfix)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tier_model():
    """Deep-prompt tiered config (as tests/test_tiered.py: shrink local
    and select_budget so the spillable page-table section dominates),
    share-window-widened so fused windows have real length."""
    cfg = _widen(reduced(get_arch("smollm-360m")))
    cfg = dataclasses.replace(cfg, h2eal=dataclasses.replace(
        cfg.h2eal, local=8, select_budget=16))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


TCAP = 128


def test_fused_tiered_force_spill_bit_exact(tier_model):
    """Chaos hook inside the fused engine: spill EVERY spillable page —
    including the currently selected ones — at a selection boundary.
    The boundary select (still per-step) detects the cold selection,
    demand-fills, and replays; the fused windows after it run on the
    repaired hot set. Residency never changes inside a window (reuse
    steps only read selected+sink+local pages, all pinned hot), so the
    fused tiered trace equals the all-resident per-step oracle bit for
    bit — the miss is served late, never skipped."""
    cfg, params = tier_model
    req = lambda: Request(uid=0, prompt=np.random.default_rng(7).integers(
        0, cfg.vocab_size, size=(64,)).astype(np.int32), max_new=14)
    ref = Engine(cfg, params, max_batch=1, capacity=TCAP,
                 prompt_buckets=[64]).run([req()])[0].tokens

    eng = Engine(cfg, params, max_batch=1, capacity=TCAP,
                 prompt_buckets=[64], hot_pages=12, decode_window=W)
    eng.submit(req())
    eng._admit()
    w = eng.share_window
    forced = 0
    steps = 0
    while eng.busy():
        b = eng.batch
        if (not forced and steps >= 2 and b.active[0]
                and b.phase[0] % w == 0):
            forced = eng.tier_force_spill(0)
        eng.step()
        steps += 1
    assert forced > 0
    eng.finalize()
    assert eng.completions[0].tokens == ref
    s = eng.stats
    assert s.fused_windows > 0, "windows never fused"
    assert s.tier_misses > 0, "forced-cold selection never missed"
    assert s.tier_fills == s.tier_misses     # each one demand-filled
    assert s.tier_hit_rate < 1.0


def test_fused_tiered_workload_matches_resident(tier_model):
    """Tight hot-set budget + fused windows over the churny tiered
    workload: spills and prefetches actually happen between windows and
    the trace stays bit-identical to the all-resident per-step oracle;
    the batched refresh path reports its transfer batch sizes."""
    from tests.test_tiered import _workload as tier_workload

    cfg, params = tier_model
    ref = {u: c.tokens for u, c in
           Engine(cfg, params, max_batch=2, capacity=TCAP,
                  prompt_buckets=[64]).run(tier_workload(cfg, 0)).items()}
    eng = Engine(cfg, params, max_batch=2, capacity=TCAP,
                 prompt_buckets=[64], hot_pages=6, decode_window=W)
    comps = eng.run(tier_workload(cfg, 0))
    assert sorted(comps) == sorted(ref)
    for uid in sorted(ref):
        assert comps[uid].tokens == ref[uid], uid
    s = eng.stats
    assert s.fused_windows > 0
    assert s.tier_spills > 0
    # satellite: plan_refresh applies as batched transfers — the batch
    # counters are live and each batch moved >= 1 page
    assert s.tier_spill_batches > 0
    assert s.tier_fill_batches > 0
    assert s.tier_batch_pages_max >= 1
    assert s.tier_fill_batch_mean >= 1.0
    assert s.tier_spill_batch_mean >= 1.0


# ---------------------------------------------------------------------------
# Sharded fused windows (8-fake-device subprocess)
# ---------------------------------------------------------------------------


FUSED_SHMAP_CODE = """
import dataclasses
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine
from tests.test_serving import CAP, _mixed_workload
from tests.test_fused_window import W, _widen, _workload

cfg = _widen(reduced(get_arch("smollm-360m")))
params = M.init_params(cfg, jax.random.PRNGKey(0))
# per-step default-layout reference on the widened config
eng0 = Engine(cfg, params, max_batch=2, capacity=CAP,
              prompt_buckets=[16, 24])
c0 = eng0.run(_workload(cfg))
# the fused engine under REAL shard_map co-placement: the scanned reuse
# body dispatches through the layout's partial-attention decode with
# pinned out-shardings, chunked prefill riding the mixed fused jit
eng1 = Engine(cfg, params, max_batch=2, capacity=CAP,
              prompt_buckets=[16, 24], layout="coplace_shmap",
              admission="balanced", prefill_chunk=7, decode_window=W)
c1 = eng1.run(_workload(cfg))
assert sorted(c0) == sorted(c1)
for uid in sorted(c0):
    assert c0[uid].tokens == c1[uid].tokens, (
        uid, c0[uid].tokens, c1[uid].tokens)
assert eng1.stats.fused_windows > 0, "windows never fused"
sizes0 = eng1.jit_cache_sizes()
assert sizes0["fused_window"] in (-1, 1), sizes0
assert sizes0["fused_window_mixed"] in (-1, 1), sizes0
eng1.reset_metrics()
c2 = eng1.run(_workload(cfg, sampled=True, seed=5, n=3))
assert eng1.jit_cache_sizes() == sizes0, (sizes0, eng1.jit_cache_sizes())
print("FUSED_SHMAP_EXACT")
"""


@pytest.mark.slow
def test_fused_coplace_shmap_exact_8dev():
    """8-fake-device subprocess (the ISSUE-10 acceptance check): the
    FUSED coplace_shmap engine — the share-window scan dispatched
    through shard_map partial attention with pinned out-shardings and
    chunked prefill inside the window — is token-exact vs the per-step
    default-layout engine, and the greedy->stochastic rerun compiles
    nothing new (zero post-warmup recompiles on the fused entries)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", FUSED_SHMAP_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "FUSED_SHMAP_EXACT" in out.stdout
