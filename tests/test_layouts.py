"""AttentionLayout registry: resolution, planning, and the layout
conformance sweep.

The sweep is the point of the registry: ONE parameterized test iterates
every registered layout and asserts the engine-level contract — token
exactness vs the ``default`` layout, slot-churn invariance, and the
zero-recompile invariant — so any future ``register_layout()`` entry
gets its conformance tests for free.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import layouts as layoutlib
from repro.models import model as M
from repro.serving import Engine, Request
from tests.test_serving import CAP, REPO, _mixed_workload

LAYOUTS = layoutlib.available_layouts()


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_arch("smollm-360m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Registry + DecodeInputs
# ---------------------------------------------------------------------------


def test_registry_resolution():
    assert set(LAYOUTS) >= {"default", "head", "coplace", "interleave",
                            "coplace_shmap"}
    # deprecated aliases (one release): None and "auto" mean default
    assert layoutlib.resolve_layout(None) == "default"
    assert layoutlib.resolve_layout("auto") == "default"
    for name in LAYOUTS:
        assert layoutlib.get_layout(name).name == name
    with pytest.raises(ValueError, match="registered layouts"):
        layoutlib.get_layout("bogus")


def test_layout_alias_deprecation_warns_once():
    """The pre-registry spellings None/"auto" resolve with a one-shot
    DeprecationWarning per spelling (mirroring kernels/ops impl="kernel");
    canonical names resolve silently."""
    import warnings

    layoutlib._warned_aliases.clear()
    try:
        with pytest.warns(DeprecationWarning, match="deprecated alias"):
            assert layoutlib.resolve_layout(None) == "default"
        with pytest.warns(DeprecationWarning, match="deprecated alias"):
            assert layoutlib.resolve_layout("auto") == "default"
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            # second resolution of each alias is silent (warns once)
            assert layoutlib.resolve_layout(None) == "default"
            assert layoutlib.resolve_layout("auto") == "default"
            # canonical names never warn
            for name in layoutlib.available_layouts():
                assert layoutlib.resolve_layout(name) == name
            # the internal (model-layer) lookup never warns at all
            layoutlib._warned_aliases.clear()
            assert layoutlib.get_layout(None).name == "default"
    finally:
        # leave the one-shot set consumed so later tests that pass the
        # aliases internally stay quiet regardless of ordering
        layoutlib._warned_aliases.update(_ALIAS_KEYS)


_ALIAS_KEYS = (None, "auto")


def test_register_custom_layout():
    """A new entry is one register_layout() call away (and is listed)."""

    class Custom(layoutlib.DefaultLayout):
        name = "custom_test_layout"

    try:
        layoutlib.register_layout(Custom())
        assert "custom_test_layout" in layoutlib.available_layouts()
        assert isinstance(layoutlib.get_layout("custom_test_layout"), Custom)
    finally:
        del layoutlib._REGISTRY["custom_test_layout"]
    with pytest.raises(ValueError, match="registered layouts"):
        layoutlib.get_layout("custom_test_layout")


def test_decode_inputs_pytree():
    di = layoutlib.DecodeInputs(
        q=jnp.ones((2, 4, 8)), k_new=jnp.ones((2, 2, 8)),
        v_new=jnp.ones((2, 2, 8)), lengths=jnp.int32(5))
    assert not di.is_ragged
    leaves, treedef = jax.tree_util.tree_flatten(di)
    assert len(leaves) == 4  # None masks are empty subtrees
    di2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert di2.active is None and di2.lengths.shape == ()
    ragged = layoutlib.DecodeInputs(
        q=di.q, k_new=di.k_new, v_new=di.v_new,
        lengths=jnp.array([3, 5], jnp.int32),
        active=jnp.array([True, False]))
    assert ragged.is_ragged


def test_base_layout_ragged_unsupported():
    class NoRagged(layoutlib.AttentionLayout):
        name = "lockstep_only"

    with pytest.raises(NotImplementedError, match="ragged"):
        NoRagged().ragged_decode(None, {}, None, do_select=False)


# ---------------------------------------------------------------------------
# Construction-time planning (the Engine mesh-validation bugfix)
# ---------------------------------------------------------------------------


def test_plan_mesh_and_capacity(model):
    cfg, _ = model
    p = cfg.h2eal.page_size
    plan_d = layoutlib.get_layout("default").plan(cfg)
    assert plan_d.mesh is None and not plan_d.shard_state
    assert plan_d.round_capacity(61) == 61

    plan_i = layoutlib.get_layout("interleave").plan(cfg)
    assert plan_i.shard_state
    assert {"data", "model"} <= set(plan_i.mesh.axis_names)
    nsh = int(plan_i.mesh.shape["model"])
    assert plan_i.capacity_quantum == p * nsh
    assert plan_i.round_capacity(p * nsh + 1) == 2 * p * nsh
    assert plan_i.balance_shards == nsh

    plan_c = layoutlib.get_layout("coplace_shmap").plan(cfg)
    assert plan_c.shard_state and plan_c.capacity_quantum == p * int(
        plan_c.mesh.shape["model"])
    # head parallelism distributes heads, not pages: no rounding, FIFO
    plan_h = layoutlib.get_layout("head").plan(cfg)
    assert plan_h.capacity_quantum == 1 and plan_h.balance_shards == 1


def test_plan_validates_mesh_axes(model):
    """A layout whose mesh requirements aren't met fails at plan/Engine
    construction time, not at the first decode step."""
    from repro.runtime.compat import make_mesh

    cfg, params = model
    n = len(jax.devices())
    no_data = make_mesh((n,), ("model",))
    with pytest.raises(ValueError, match="'data'"):
        layoutlib.get_layout("interleave").plan(cfg, no_data)
    no_model = make_mesh((n,), ("data",))
    with pytest.raises(ValueError, match="'model'"):
        layoutlib.get_layout("coplace_shmap").plan(cfg, no_model)
    with pytest.raises(ValueError, match="'data'"):
        Engine(cfg, params, max_batch=1, capacity=CAP, prompt_buckets=[16],
               layout="interleave", mesh=no_data)


def test_engine_resolves_layout_through_registry(model):
    cfg, params = model
    with pytest.raises(ValueError, match="registered layouts"):
        Engine(cfg, params, max_batch=1, capacity=CAP, prompt_buckets=[16],
               layout="bogus")
    eng = Engine(cfg, params, max_batch=1, capacity=CAP, prompt_buckets=[16],
                 layout=None)   # deprecated alias
    assert eng.layout == "default" and eng.plan.layout == "default"


def test_state_shardings_resolve_through_registry(model):
    from repro.runtime import sharding as shardlib
    from repro.runtime.compat import make_mesh

    cfg, _ = model
    mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
    with pytest.raises(ValueError, match="registered layouts"):
        shardlib.state_shardings(cfg, mesh, {"x": jnp.zeros((4, 4))},
                                 layout="bogus")


# ---------------------------------------------------------------------------
# The conformance sweep: every registered layout, for free
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def default_trace(model):
    """Reference tokens from the default layout: one mixed (churny)
    workload + the first request served solo."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24])
    reqs = _mixed_workload(cfg, n=3)
    mixed = {u: c.tokens for u, c in eng.run(reqs).items()}
    eng.reset_metrics()
    solo = eng.run([Request(uid=100, prompt=reqs[0].prompt,
                            max_new=reqs[0].max_new)])
    return reqs, mixed, solo[100].tokens


@pytest.mark.parametrize("name", LAYOUTS)
def test_layout_conformance(model, default_trace, name):
    """Engine contract per registered layout: (1) token-exact vs the
    default layout for the same admission trace, (2) slot-churn
    invariance (a request's tokens are identical served solo or amid
    churn), (3) no recompiles across differently-shaped workloads.
    Token-exactness holds off argmax ties (EXPERIMENTS.md §Serving
    experiments)."""
    cfg, params = model
    reqs, mixed_ref, solo_ref = default_trace
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout=name)
    assert eng.layout == name
    mixed = eng.run(_mixed_workload(cfg, n=3))
    assert sorted(mixed) == sorted(mixed_ref)
    for uid in sorted(mixed_ref):
        assert mixed[uid].tokens == mixed_ref[uid], (name, uid)
    sizes0 = eng.jit_cache_sizes()
    eng.reset_metrics()
    solo = eng.run([Request(uid=100, prompt=reqs[0].prompt,
                            max_new=reqs[0].max_new)])
    assert solo[100].tokens == solo_ref, name          # vs default
    assert solo[100].tokens == mixed_ref[0], name      # churn invariance
    assert eng.jit_cache_sizes() == sizes0, name       # no recompiles


@pytest.mark.parametrize("name", LAYOUTS)
def test_layout_conformance_chunked(model, default_trace, name):
    """Chunked-prefill conformance, for free per registry entry: the
    engine with ``prefill_chunk`` set streams prompts into the layout's
    caches through its ``prefill_chunk`` hook and must reproduce the
    default-layout prefill-then-pack token trace for the same admission
    trace, with zero post-warmup recompiles. Future layouts inherit this
    sweep the moment they register."""
    cfg, params = model
    _, mixed_ref, _ = default_trace
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout=name, prefill_chunk=5)
    mixed = eng.run(_mixed_workload(cfg, n=3))
    assert sorted(mixed) == sorted(mixed_ref)
    for uid in sorted(mixed_ref):
        assert mixed[uid].tokens == mixed_ref[uid], (name, uid)
    assert eng.stats.prefill_chunks > 0
    sizes0 = eng.jit_cache_sizes()
    eng.reset_metrics()
    eng.run(_mixed_workload(cfg, seed=11, n=2))
    assert eng.jit_cache_sizes() == sizes0, name       # no recompiles


@pytest.mark.parametrize("name", LAYOUTS)
def test_layout_conformance_tiered(model, default_trace, name):
    """Tiered-residency conformance, for free per registry entry: the
    engine with ``hot_pages`` set spills/prefetches cold KV pages
    through the layout's residency plan (LayoutPlan.page_stripe_shards
    maps logical pins to physical pages under striped layouts) and must
    emit the ALL-RESIDENT default-layout token trace bit-identically,
    with zero post-warmup recompiles. Future layouts inherit this sweep
    the moment they register."""
    cfg, params = model
    _, mixed_ref, _ = default_trace
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout=name, hot_pages=4)
    mixed = eng.run(_mixed_workload(cfg, n=3))
    assert sorted(mixed) == sorted(mixed_ref)
    for uid in sorted(mixed_ref):
        assert mixed[uid].tokens == mixed_ref[uid], (name, uid)
    sizes0 = eng.jit_cache_sizes()
    assert {"tier_gather", "tier_spill", "tier_fill"} <= set(sizes0), name
    eng.reset_metrics()
    eng.run(_mixed_workload(cfg, seed=11, n=2))
    assert eng.jit_cache_sizes() == sizes0, name       # no recompiles


@pytest.fixture(scope="module")
def fused_trace(model):
    """Per-step reference on a share-window-widened config: the reduced
    config pins share_window=2, which leaves fused windows a single
    scan iteration — widening to 4 gives the fused scan real length.
    (share_window only changes the selection cadence, never parameter
    shapes, so the module params are reused.)"""
    import dataclasses

    cfg, params = model
    wcfg = dataclasses.replace(
        cfg, h2eal=dataclasses.replace(cfg.h2eal, share_window=4))
    eng = Engine(wcfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24])
    mixed = {u: c.tokens
             for u, c in eng.run(_mixed_workload(wcfg, n=3)).items()}
    return wcfg, mixed


@pytest.mark.parametrize("name", LAYOUTS)
def test_layout_conformance_fused(model, fused_trace, name):
    """Fused decode-window conformance, for free per registry entry:
    ``Engine(decode_window=w)`` routes the share-window scan through
    the layout's ``decode_window`` hook (core/layouts.py — the default
    implementation jit-scans the layout's own reuse body), so every
    layout including the shard_map co-placement entry must reproduce
    the per-step token trace bit-identically, keep one compiled
    ``fused_window`` entry, and never recompile across
    differently-shaped workloads. Future layouts inherit this sweep the
    moment they register (docs/serving.md §Fused decode windows)."""
    _, params = model
    wcfg, mixed_ref = fused_trace
    eng = Engine(wcfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout=name, decode_window=4)
    mixed = eng.run(_mixed_workload(wcfg, n=3))
    assert sorted(mixed) == sorted(mixed_ref)
    for uid in sorted(mixed_ref):
        assert mixed[uid].tokens == mixed_ref[uid], (name, uid)
    assert eng.stats.fused_windows > 0, name
    sizes0 = eng.jit_cache_sizes()
    assert sizes0["fused_window"] in (-1, 1), (name, sizes0)
    eng.reset_metrics()
    eng.run(_mixed_workload(wcfg, seed=11, n=2))
    assert eng.jit_cache_sizes() == sizes0, name       # no recompiles


def _sampled_workload(cfg, *, n=3, seed=2, temperature=0.8, top_p=0.9):
    """The mixed churny workload with stochastic sampling params; RNG
    keys are owned by (request.seed, uid), so the same list reproduces
    the same trace on any engine configuration."""
    import dataclasses

    return [dataclasses.replace(r, temperature=temperature, top_p=top_p)
            for r in _mixed_workload(cfg, seed=seed, n=n)]


@pytest.fixture(scope="module")
def sampled_trace(model):
    """Reference stochastic tokens from the default layout."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24])
    return {u: c.tokens for u, c in eng.run(_sampled_workload(cfg)).items()}


@pytest.mark.parametrize("name", LAYOUTS)
def test_layout_conformance_sampled(model, sampled_trace, name):
    """Stochastic-sampling conformance, for free per registry entry:
    per-request RNG key lanes make the sampled trace a function of
    (seed, uid, generation index) only, so every layout must reproduce
    the default layout's stochastic tokens exactly, with zero
    post-warmup recompiles (temperature/top_p are jit INPUTS, so the
    greedy and sampled paths share one compiled program)."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout=name)
    mixed = eng.run(_sampled_workload(cfg))
    assert sorted(mixed) == sorted(sampled_trace)
    for uid in sorted(sampled_trace):
        assert mixed[uid].tokens == sampled_trace[uid], (name, uid)
    sizes0 = eng.jit_cache_sizes()
    eng.reset_metrics()
    eng.run(_sampled_workload(cfg, seed=11, n=2))
    assert eng.jit_cache_sizes() == sizes0, name       # no recompiles


@pytest.mark.parametrize("name", LAYOUTS)
def test_layout_conformance_speculative(model, default_trace, sampled_trace,
                                        name):
    """Speculative-decode conformance, for free per registry entry: the
    coupled rejection sampler makes Engine(spec_tokens=k) emit the
    EXACT non-speculative trace — greedy (bit-identical argmax) AND
    stochastic — under every layout, through the layout's own
    verify_chunk/verify_append hooks. One engine serves both workloads:
    the verify jit compiles for one static (B, k) bucket and must not
    grow new entries when temperature flips from 0 to 0.8 or across
    differently-shaped reruns (the zero-post-warmup-recompile invariant
    with sampling + speculation enabled)."""
    cfg, params = model
    _, mixed_ref, _ = default_trace
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout=name, spec_tokens=4)
    mixed = eng.run(_mixed_workload(cfg, n=3))
    assert sorted(mixed) == sorted(mixed_ref)
    for uid in sorted(mixed_ref):
        assert mixed[uid].tokens == mixed_ref[uid], (name, uid)
    assert eng.stats.spec_steps > 0
    assert eng.stats.mean_accepted_len >= 1.0
    sizes0 = eng.jit_cache_sizes()
    assert sizes0["verify"] >= 1, name
    eng.reset_metrics()
    sampled = eng.run(_sampled_workload(cfg))
    assert sorted(sampled) == sorted(sampled_trace)
    for uid in sorted(sampled_trace):
        assert sampled[uid].tokens == sampled_trace[uid], (name, uid)
    # the greedy->stochastic flip and the rerun compiled NOTHING new:
    # draft/verify/accept all reuse the warm bodies
    assert eng.jit_cache_sizes() == sizes0, name


@pytest.fixture(scope="module")
def hybrid_model():
    """An attention+mamba2 hybrid: the recurrent chunk-resume path must
    conform on every layout, not just the attention-only config."""
    cfg = reduced(get_arch("zamba2-2.7b"),
                  mixer_pattern=("mamba2", "mamba2", "attention"),
                  num_layers=3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24])
    mixed = {u: c.tokens for u, c in eng.run(_mixed_workload(cfg, n=3)).items()}
    return cfg, params, mixed


@pytest.mark.parametrize("name", LAYOUTS)
def test_layout_conformance_chunked_recurrent(hybrid_model, name):
    """Chunked-prefill conformance on a recurrent hybrid, per registry
    entry: layouts own only the ATTENTION caches, so the per-slot scan
    state (mamba2 ssm/conv) must resume identically under every layout —
    token-exact vs the default-layout packed trace, zero post-warmup
    recompiles."""
    cfg, params, mixed_ref = hybrid_model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout=name, prefill_chunk=5)
    mixed = eng.run(_mixed_workload(cfg, n=3))
    assert sorted(mixed) == sorted(mixed_ref)
    for uid in sorted(mixed_ref):
        assert mixed[uid].tokens == mixed_ref[uid], (name, uid)
    assert eng.stats.prefill_chunks > 0
    sizes0 = eng.jit_cache_sizes()
    eng.reset_metrics()
    eng.run(_mixed_workload(cfg, seed=11, n=2))
    assert eng.jit_cache_sizes() == sizes0, name       # no recompiles


# ---------------------------------------------------------------------------
# Tiered residency under real sharding (8-fake-device subprocess)
# ---------------------------------------------------------------------------


TIERED_SHMAP_CODE = """
import dataclasses
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine
from tests.test_tiered import CAP, _workload

cfg = reduced(get_arch("smollm-360m"))
cfg = dataclasses.replace(cfg, h2eal=dataclasses.replace(
    cfg.h2eal, local=8, select_budget=16))
params = M.init_params(cfg, jax.random.PRNGKey(0))
# CAP=128 -> 16 pages over 8 shards: the physical striping is genuinely
# permuted (logical page p lives at (p % 8) * 2 + p // 8), so the tier
# bitmap, spills, and prefetches all run in remapped page space
eng0 = Engine(cfg, params, max_batch=2, capacity=CAP, prompt_buckets=[64])
c0 = eng0.run(_workload(cfg, 0))
eng1 = Engine(cfg, params, max_batch=2, capacity=CAP, prompt_buckets=[64],
              layout="coplace_shmap", hot_pages=6)
assert eng1.plan.page_stripe_shards == 8
c1 = eng1.run(_workload(cfg, 0))
assert sorted(c0) == sorted(c1)
for uid in sorted(c0):
    assert c0[uid].tokens == c1[uid].tokens, (
        uid, c0[uid].tokens, c1[uid].tokens)
assert eng1.stats.tier_spills > 0, "tiering never spilled"
sizes0 = eng1.jit_cache_sizes()
eng1.reset_metrics()
eng1.run(_workload(cfg, 1))
assert eng1.jit_cache_sizes() == sizes0, (sizes0, eng1.jit_cache_sizes())
print("TIERED_SHMAP_EXACT")
"""


@pytest.mark.slow
def test_layout_tiered_coplace_shmap_8dev():
    """8-fake-device subprocess: the TIERED coplace_shmap engine — tier
    residency tracked in the striped physical page space — is
    token-exact vs the all-resident default-layout engine, actually
    spills, and never recompiles after warmup."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", TIERED_SHMAP_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "TIERED_SHMAP_EXACT" in out.stdout


SPEC_SHMAP_CODE = """
import dataclasses
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine
from tests.test_layouts import _sampled_workload
from tests.test_serving import CAP, _mixed_workload

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
# greedy + stochastic references from the non-speculative default engine
eng0 = Engine(cfg, params, max_batch=2, capacity=CAP, prompt_buckets=[16, 24])
g0 = eng0.run(_mixed_workload(cfg, n=3))
s0 = eng0.run(_sampled_workload(cfg))
# the speculative engine under REAL shard_map co-placement: the verify
# chunk flows through the layout's partial-attention body on 8 devices
eng1 = Engine(cfg, params, max_batch=2, capacity=CAP, prompt_buckets=[16, 24],
              layout="coplace_shmap", spec_tokens=4)
g1 = eng1.run(_mixed_workload(cfg, n=3))
assert sorted(g0) == sorted(g1)
for uid in sorted(g0):
    assert g0[uid].tokens == g1[uid].tokens, (
        uid, g0[uid].tokens, g1[uid].tokens)
assert eng1.stats.spec_steps > 0, "speculation never dispatched"
sizes0 = eng1.jit_cache_sizes()
assert sizes0["verify"] >= 1
s1 = eng1.run(_sampled_workload(cfg))
assert sorted(s0) == sorted(s1)
for uid in sorted(s0):
    assert s0[uid].tokens == s1[uid].tokens, (
        uid, s0[uid].tokens, s1[uid].tokens)
assert eng1.jit_cache_sizes() == sizes0, (sizes0, eng1.jit_cache_sizes())
print("SPEC_SHMAP_EXACT")
"""


@pytest.mark.slow
def test_layout_speculative_coplace_shmap_8dev():
    """8-fake-device subprocess: the SPECULATIVE coplace_shmap engine —
    the (B, k) verify chunk dispatched through shard_map partial
    attention with pinned out-shardings — emits the non-speculative
    default-layout trace bit-identically (greedy and stochastic), and
    the greedy->stochastic flip plus rerun compile nothing new."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", SPEC_SHMAP_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SPEC_SHMAP_EXACT" in out.stdout
