"""Scheduler properties: mapping (cases a/b/c), tiling, load balance."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import H2ealConfig
from repro.sched import (
    balanced_loads,
    grid_coords,
    head_load,
    imbalance,
    map_heads,
    manhattan,
    solve_tiling,
    unbalanced_loads,
)


@settings(deadline=None, max_examples=120)
@given(n_h=st.integers(1, 128), n_b=st.integers(1, 64))
def test_mapping_partitions_heads_exactly(n_h, n_b):
    plan = map_heads(n_h, n_b)
    plan.validate()  # internal asserts: exact head partition, bank counts
    # every stage uses all banks (work + idle == n_b)
    for s in plan.stages:
        assert len(s.heads) * s.banks_per_head + s.idle_banks == n_b


def test_mapping_paper_cases():
    """The paper's own examples: 40 (Vicuna-13B), 32 (LLaMA-2-7B), 16
    (DeepSeek-V2-Lite) KV heads on a 4x4 = 16-bank array."""
    p40 = map_heads(40, 16)
    assert [len(s.heads) for s in p40.stages] == [16, 16, 8]
    p32 = map_heads(32, 16)
    assert [len(s.heads) for s in p32.stages] == [16, 16]
    p16 = map_heads(16, 16)
    assert p16.num_stages == 1
    assert p16.stages[0].banks_per_head == 1
    # case (c): greedy distinct divisors (15 = 8+4+2+1)
    p15 = map_heads(15, 16)
    assert [len(s.heads) for s in p15.stages] == [8, 4, 2, 1]
    assert [s.banks_per_head for s in p15.stages] == [2, 4, 8, 16]
    # greedy-infeasible fallback with idle banks
    p59 = map_heads(5, 9)
    assert p59.total_idle == 4


def test_tiling_minimizes_distance_corner_case():
    """4 retrieval heads at corners of a 4x4 grid: optimal max distance is
    2 (each corner anchors its quadrant)."""
    coords = grid_coords(4, 4)
    retr = [(0, 0), (0, 3), (3, 0), (3, 3)]
    stream = [c for c in coords if c not in retr]
    tiles, d = solve_tiling(retr, stream)
    assert d == 2
    assert len(tiles) == 4
    assert all(len(t.members) == 4 for t in tiles)
    # every bank appears exactly once
    all_members = [m for t in tiles for m in t.members]
    assert sorted(all_members) == sorted(coords)


def test_tiling_adjacent_pairs():
    """n_r == n_s on a line: pairs of adjacent banks, distance 1."""
    retr = [(0, i) for i in range(0, 8, 2)]
    stream = [(0, i) for i in range(1, 8, 2)]
    tiles, d = solve_tiling(retr, stream)
    assert d == 1
    assert all(t.max_dist <= 1 for t in tiles)


@settings(deadline=None, max_examples=40)
@given(n_r=st.integers(1, 8), n_s=st.integers(1, 8))
def test_tiling_feasible_any_mix(n_r, n_s):
    coords = grid_coords(4, 4)[: n_r + n_s]
    retr, stream = coords[:n_r], coords[n_r:]
    tiles, d = solve_tiling(retr, stream)
    t_expect = min(n_r, n_s)
    assert len(tiles) == t_expect
    cap = -(-(n_r + n_s) // t_expect)
    assert all(len(t.members) <= cap for t in tiles)
    all_members = [m for t in tiles for m in t.members]
    assert sorted(all_members) == sorted(coords)


def test_balancing_removes_imbalance():
    """Paper Fig 11: co-placement balances retrieval vs streaming load."""
    coords = grid_coords(4, 4)
    retr = coords[:4]
    stream = coords[4:]
    tiles, _ = solve_tiling(retr, stream)
    kinds = {c: ("retrieval" if c in retr else "streaming") for c in coords}
    h2 = H2ealConfig()
    u = unbalanced_loads(tiles, kinds, h2, pages=8192)
    b = balanced_loads(tiles, kinds, h2, pages=8192)
    assert imbalance(u) > 2.0      # naive placement is badly imbalanced
    assert imbalance(b) < 1.01     # co-placement is exact
    # total work is conserved
    assert abs(sum(x.load for x in u) - sum(x.load for x in b)) < 1e-6


def test_head_load_model():
    h2 = H2ealConfig(sink=4, local=256, select_budget=4096, page_size=32)
    s = head_load("streaming", h2)
    r = head_load("retrieval", h2, metadata_scan_pages=8192)
    assert s == 260
    assert r > 4096  # dominated by the selected tokens
    assert r / s > 10  # the imbalance the paper's Fig 11 shows


# ---------------------------------------------------------------------------
# map_slots (greedy-LPT whole-slot placement) edge cases — the rebalance
# planner (sched/rebalance.py) uses its assignment as the migration target,
# so degenerate inputs must stay well-defined and deterministic.
# ---------------------------------------------------------------------------

def _assert_partition(asn, n_slots):
    placed = sorted(s for bank in asn.banks for s in bank)
    assert placed == list(range(n_slots))


def test_map_slots_tied_loads_deterministic():
    """All-equal loads: the sort is stable and the argmin breaks ties on
    the lowest bank index, so placement is index-round-robin and
    identical on every call."""
    from repro.sched import map_slots

    loads = [5.0] * 6
    a = map_slots(loads, 3)
    _assert_partition(a, 6)
    assert a.banks == ((0, 3), (1, 4), (2, 5))
    assert a.loads == (10.0, 10.0, 10.0)
    assert a.imbalance == 1.0
    for _ in range(3):
        b = map_slots(loads, 3)
        assert b.banks == a.banks and b.loads == a.loads


def test_map_slots_zero_loads():
    """Zero-load slots (e.g. freshly admitted, ctx 0) still partition
    exactly once and score as perfectly balanced, not a div-by-zero."""
    from repro.sched import map_slots

    a = map_slots([0.0, 0.0, 0.0, 0.0], 2)
    _assert_partition(a, 4)
    assert a.loads == (0.0, 0.0)
    assert a.imbalance == 1.0  # load_imbalance's zero-mean convention


def test_map_slots_more_banks_than_slots():
    """n_banks > len(slot_loads): every slot gets its own bank, the
    surplus banks stay empty at zero load, and total load is conserved."""
    from repro.sched import map_slots

    loads = [7.0, 3.0]
    a = map_slots(loads, 5)
    _assert_partition(a, 2)
    assert sum(len(b) for b in a.banks) == 2
    assert max(len(b) for b in a.banks) == 1
    empty = [l for b, l in zip(a.banks, a.loads) if not b]
    assert empty == [0.0, 0.0, 0.0]
    assert sum(a.loads) == pytest.approx(sum(loads))


def test_map_slots_empty_and_single():
    from repro.sched import map_slots

    none = map_slots([], 3)
    assert none.banks == ((), (), ())
    assert none.imbalance == 1.0
    one = map_slots([9.0], 3)
    _assert_partition(one, 1)
    assert one.banks[0] == (0,) and one.loads[0] == 9.0


@settings(deadline=None, max_examples=60)
@given(n_slots=st.integers(0, 24), n_banks=st.integers(1, 8),
       seed=st.integers(0, 1 << 16))
def test_map_slots_partition_and_determinism(n_slots, n_banks, seed):
    import random

    from repro.sched import map_slots

    loads = [random.Random(seed + i).uniform(0.0, 1e6)
             for i in range(n_slots)]
    a = map_slots(loads, n_banks)
    b = map_slots(list(loads), n_banks)
    _assert_partition(a, len(loads))
    assert a.banks == b.banks and a.loads == b.loads  # pure + deterministic
    assert sum(a.loads) == pytest.approx(sum(loads), abs=1e-6)
    # LPT never loads a bank beyond (max slot + mean) — the classic bound
    if loads:
        mean = sum(loads) / n_banks
        assert max(a.loads) <= mean + max(loads) + 1e-6
