"""Hypothesis shim: real property testing when `hypothesis` is installed,
seeded example-based degradation when it is not.

The tier-1 environment pins only runtime deps; `hypothesis` lives in the
dev extra (see pyproject.toml / requirements-dev.txt). Collection must
succeed either way, so property tests import from this module:

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real objects. Without it,
``@given`` degrades to running the test over a deterministic handful of
drawn examples per strategy — always including the strategy bounds, plus
seeded random draws — and ``@settings`` only caps the number of examples.
Only the strategy surface this repo uses is shimmed (integers, floats,
sampled_from).
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _SHIM_EXAMPLES = 12  # draws per strategy when degraded (incl. bounds)

    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi, self._draw = lo, hi, draw

        def examples(self, rng, n):
            out = [self.lo, self.hi]
            out.extend(self._draw(rng) for _ in range(max(n - 2, 0)))
            return out[:n]

    class _StrategiesShim:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(min_value, max_value,
                             lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(min_value, max_value,
                             lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(elements[0], elements[-1],
                             lambda rng: rng.choice(elements))

    st = _StrategiesShim()

    def given(**strategies):
        def deco(fn):
            def run(*args, **kwargs):
                n = min(getattr(run, "_shim_max_examples", _SHIM_EXAMPLES),
                        _SHIM_EXAMPLES)
                rng = random.Random(f"hyp-shim:{fn.__module__}.{fn.__name__}")
                names = sorted(strategies)
                drawn = {k: strategies[k].examples(rng, n) for k in names}
                for i in range(n):
                    ex = {k: drawn[k][i] for k in names}
                    try:
                        fn(*args, **dict(kwargs, **ex))
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (hypothesis shim): {ex}"
                        ) from e
            # hide the strategy params from pytest's fixture resolution
            # (functools.wraps would re-expose them via __wrapped__)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            sig = inspect.signature(fn)
            run.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return run
        return deco

    def settings(*, max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn
        return deco
