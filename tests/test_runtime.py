"""Sharding rules + dry-run mini (subprocess with fake devices)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 16, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_param_and_state_shardings_valid():
    """Every param/state leaf gets a sharding consistent with its shape on
    a (2 data x 2 model) mesh; device_put-compatible."""
    code = """
import jax, jax.numpy as jnp
from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.runtime import sharding as shardlib, serve as serve_rt
from repro.runtime.compat import make_mesh
from repro.launch import specs as S

mesh = make_mesh((2, 2), ("data", "model"))
for name in ("smollm-360m", "qwen3-moe-235b-a22b", "zamba2-2.7b",
             "xlstm-125m"):
    cfg = reduced(get_arch(name))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ps = shardlib.param_shardings(cfg, mesh, params)
    placed = jax.device_put(params, ps)          # would raise on mismatch
    scfg = serve_rt.ServeConfig(capacity=64)
    batch = jnp.zeros((4, 32), jnp.int32) if not cfg.embed_frontend_stub \
        else jnp.zeros((4, 32, cfg.d_model))
    state = jax.eval_shape(serve_rt.make_prefill(cfg, scfg), params, batch)[1]
    ss = shardlib.state_shardings(cfg, mesh, state, batch_size=4)
    jax.tree.map(lambda l, s: s.shard_shape(l.shape), state, ss)
    print(name, "ok")
print("ALL_OK")
"""
    out = _run_py(code, devices=4)
    assert "ALL_OK" in out


@pytest.mark.slow
def test_dryrun_single_cell_production_mesh():
    """Full production-mesh (16x16=256 fake devices) lower+compile of one
    assigned cell, plus a multi-pod (2x16x16) cell."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import lower_cell
r1 = lower_cell("smollm-360m", "decode_32k", multi_pod=False)
assert "error" not in r1 and r1["roofline"]["memory_s"] > 0
r2 = lower_cell("smollm-360m", "long_500k", multi_pod=True)
assert r2["chips"] == 512
print("DRYRUN_OK", r1["roofline"]["dominant"], r2["roofline"]["dominant"])
"""
    out = _run_py(code, devices=512)
    assert "DRYRUN_OK" in out


def test_hlo_stats_collective_parser():
    from repro.runtime import hlo_stats
    hlo = """
ENTRY %main () -> f32[8] {
  %x = f32[128,16]{1,0} parameter(0)
  %ag = f32[256,16]{1,0} all-gather(%x), replica_groups={}
  %ar = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), to_apply=%sum
  %rs = bf16[64]{0} reduce-scatter(%y), dimensions={0}
  %ars = f32[4]{0} all-reduce-start(%c)
  %ard = f32[4]{0} all-reduce-done(%ars)
}
"""
    s = hlo_stats.collective_stats(hlo)
    assert s["all-gather"]["bytes"] == 256 * 16 * 4
    assert s["all-reduce"]["bytes"] == 8 * 4 * 2 + 4 * 4  # tuple + start
    assert s["all-reduce"]["count"] == 2                   # done skipped
    assert s["reduce-scatter"]["bytes"] == 64 * 2


def test_perfmodel_sanity():
    """Analytical byte model: H²EAL decode ≪ full-attention decode."""
    from repro.configs import get_arch
    from repro.configs.base import SHAPES
    from repro.runtime import perfmodel
    import dataclasses

    cfg = get_arch("llama2-7b")
    shape = SHAPES["decode_32k"]
    mesh = perfmodel.MeshModel(chips=256, data=16, model=16)
    sparse = perfmodel.decode_bytes(cfg, shape, mesh, layout="head")
    cfg_full = dataclasses.replace(
        cfg, h2eal=dataclasses.replace(cfg.h2eal, enabled=False))
    full = perfmodel.decode_bytes(cfg_full, shape, mesh, layout="head")
    ratio = full["total"] / sparse["total"]
    assert ratio > 3, f"sparse attention should cut decode bytes, r={ratio}"
