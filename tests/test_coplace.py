"""Distributed co-placement (shard_map) decode: exactness vs the
single-device path, on 8 fake devices (subprocess)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.hybrid_attention import (AttnSpec, init_decode_state,
                                         decode_attention,
                                         decode_attention_coplace)
from repro.configs.base import H2ealConfig
from repro.runtime.hints import sharding_hints
from repro.runtime.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
B, Hq, Hkv, D = 2, 4, 2, 32
S, P_, sink, local = 96, 8, 2, 16
h2 = H2ealConfig(sink=sink, local=local, page_size=P_, select_budget=32,
                 share_window=2)
spec = AttnSpec(n_q=Hq, n_kv=Hkv, head_dim=D, h2=h2)
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 2)
k = jax.random.normal(ks[0], (B, S, Hkv, D))
v = jax.random.normal(ks[1], (B, S, Hkv, D))
pg_s, st_s = init_decode_state(spec, k, v, S, capacity=128)
pg_c, st_c = init_decode_state(spec, k, v, S, capacity=128,
                               interleave_shards=4)
L = jnp.int32(S)
with mesh, sharding_hints(True):
    f_std = jax.jit(lambda q, kn, vn, pg, st, l, s: decode_attention(
        spec, q, kn, vn, pg, st, l, do_select=s), static_argnums=(6,))
    f_cop = jax.jit(lambda q, kn, vn, pg, st, l, s: decode_attention_coplace(
        spec, q, kn, vn, pg, st, l, do_select=s), static_argnums=(6,))
    for step in range(6):
        kk = jax.random.split(jax.random.fold_in(key, 100 + step), 3)
        qn = jax.random.normal(kk[0], (B, Hq, D))
        kn = jax.random.normal(kk[1], (B, Hkv, D))
        vn = jax.random.normal(kk[2], (B, Hkv, D))
        sel = step % 2 == 0  # exercise shared-selection reuse too
        o1, pg_s, st_s = f_std(qn, kn, vn, pg_s, st_s, L, sel)
        o2, pg_c, st_c = f_cop(qn, kn, vn, pg_c, st_c, L, sel)
        err = float(jnp.max(jnp.abs(o1 - o2)))
        assert err < 1e-4, (step, err)
        L = L + 1
print("COPLACE_EXACT")
"""


@pytest.mark.slow
def test_coplace_decode_exact_vs_standard():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=520)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "COPLACE_EXACT" in out.stdout
