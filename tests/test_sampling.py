"""Stochastic sampling + speculative decode: the equivalence battery.

Three layers of proof that PR 8 changes HOW tokens are produced but
never WHICH tokens:

  1. Unit coupling properties of serving/sampling.py — the verify-chunk
     sampler consumes EXACTLY the per-(seed, uid, generation-index) key
     stream of the step-by-step sampler, and temperature 0 is bitwise
     argmax.
  2. Engine equivalences — greedy speculative traces are bit-identical
     to the non-speculative engine for k in {1,2,4,8} across packed AND
     chunked admission amid slot churn; stochastic traces are invariant
     to slot assignment, admission order, and spec_tokens (property
     test over temperature/top_p/seed via the hypothesis shim).
  3. Forced extremes via DraftProvider test doubles — all-reject
     (ConstantDraft) degenerates exactly to the baseline one-token
     step; all-accept (ReplayDraft + share_window >= k) emits k tokens
     per verify event, pinning the accepted-length stats and the
     steps_per_s vs tokens_per_s split.
"""
import dataclasses
import sys
import os

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.configs import get_arch, reduced  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serving import Engine, Request  # noqa: E402
from repro.serving import sampling as samplib  # noqa: E402
from repro.serving.draft import (ConstantDraft, NgramDraft,  # noqa: E402
                                 ReplayDraft, resolve_draft)

CAP = 64


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_arch("smollm-360m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)


def _requests(cfg, *, n=5, temperature=0.0, top_p=1.0, seed=0):
    """Churny workload: ragged budgets through few slots recycles slots
    mid-run, so every equivalence below is also a slot-churn test."""
    return [Request(uid=i, prompt=_prompt(cfg, [16, 24][i % 2], 7 + i),
                    max_new=3 + 2 * i, temperature=temperature,
                    top_p=top_p, seed=seed)
            for i in range(n)]


def _run(cfg, params, reqs, *, max_batch=2, **kw):
    eng = Engine(cfg, params, max_batch=max_batch, capacity=CAP,
                 prompt_buckets=[16, 24], **kw)
    comps = eng.run(reqs)
    return {u: c.tokens for u, c in comps.items()}, eng


# ---------------------------------------------------------------------------
# 1. Unit properties of the sampler
# ---------------------------------------------------------------------------


def test_sampling_params_validate():
    samplib.SamplingParams().validate()
    samplib.SamplingParams(temperature=0.7, top_p=0.9, seed=3).validate()
    with pytest.raises(ValueError, match="temperature"):
        samplib.SamplingParams(temperature=-0.1).validate()
    with pytest.raises(ValueError, match="top_p"):
        samplib.SamplingParams(top_p=0.0).validate()
    with pytest.raises(ValueError, match="top_p"):
        samplib.SamplingParams(top_p=1.5).validate()


def test_greedy_lane_is_argmax():
    rng = np.random.default_rng(0)
    logits = jax.numpy.asarray(rng.normal(size=(4, 37)).astype(np.float32))
    base = jax.numpy.stack([samplib.request_key(0, u) for u in range(4)])
    toks = samplib.sample_tokens(
        logits, base, np.zeros(4, np.int32), np.zeros(4, np.float32),
        np.ones(4, np.float32))
    assert (np.asarray(toks) == np.argmax(np.asarray(logits), -1)).all()


def test_tiny_top_p_is_argmax():
    """top_p -> 0 keeps only the most probable token: the stochastic
    lane must then agree with argmax at any temperature."""
    rng = np.random.default_rng(1)
    logits = jax.numpy.asarray(rng.normal(size=(6, 53)).astype(np.float32))
    base = jax.numpy.stack([samplib.request_key(9, u) for u in range(6)])
    toks = samplib.sample_tokens(
        logits, base, np.arange(6, dtype=np.int32),
        np.full(6, 1.3, np.float32), np.full(6, 1e-6, np.float32))
    assert (np.asarray(toks) == np.argmax(np.asarray(logits), -1)).all()


@given(seed=st.integers(min_value=0, max_value=1 << 20),
       temperature=st.floats(min_value=0.0, max_value=2.0),
       top_p=st.floats(min_value=0.05, max_value=1.0))
@settings(max_examples=8, deadline=None)
def test_chunk_sampler_coupled_to_step_sampler(seed, temperature, top_p):
    """THE losslessness lemma: column j of ``sample_chunk`` equals the
    step-by-step ``sample_tokens`` at generation index gen + j — the
    verify step's targets ARE the tokens the non-speculative engine
    would sample, for every (seed, temperature, top_p)."""
    B, k, V = 3, 5, 41
    rng = np.random.default_rng(seed)
    logits = jax.numpy.asarray(rng.normal(size=(B, k, V)).astype(np.float32))
    base = jax.numpy.stack([samplib.request_key(seed % 97, u)
                           for u in range(B)])
    gen = np.asarray([0, 3, 11], np.int32)
    t = np.full(B, temperature, np.float32)
    p = np.full(B, top_p, np.float32)
    chunk = np.asarray(samplib.sample_chunk(logits, base, gen, t, p))
    for j in range(k):
        step = np.asarray(samplib.sample_tokens(
            logits[:, j], base, gen + j, t, p))
        assert (chunk[:, j] == step).all(), j


# ---------------------------------------------------------------------------
# 2. Engine equivalences
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def greedy_baseline(model):
    cfg, params = model
    toks, _ = _run(cfg, params, _requests(cfg))
    return toks


@pytest.mark.parametrize("prefill_chunk", [None, 8])
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_greedy_speculative_trace_exact(model, greedy_baseline, k,
                                        prefill_chunk):
    """Greedy ``spec_tokens=k`` is bit-identical to ``spec_tokens=None``
    for every k, under packed AND chunked admission, amid slot churn —
    and never recompiles after its first drained workload."""
    cfg, params = model
    toks, eng = _run(cfg, params, _requests(cfg), spec_tokens=k,
                     prefill_chunk=prefill_chunk)
    assert toks == greedy_baseline
    assert eng.stats.spec_steps > 0
    sizes0 = eng.jit_cache_sizes()
    assert sizes0["verify"] == 1, sizes0
    eng.reset_metrics()
    comps = eng.run(_requests(cfg, n=3))
    assert {u: c.tokens for u, c in comps.items()} == {
        u: greedy_baseline[u] for u in comps}
    assert eng.jit_cache_sizes() == sizes0    # zero post-warmup recompiles


@pytest.fixture(scope="module")
def sampling_engines(model):
    """One engine per shape, reused across property examples so jits
    compile once: baseline 2-slot, reordered 4-slot, speculative k=4."""
    cfg, params = model
    base = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24])
    churn = Engine(cfg, params, max_batch=4, capacity=CAP,
                   prompt_buckets=[16, 24])
    spec = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24], prefill_chunk=8,
                  spec_tokens=4)
    return cfg, base, churn, spec


@given(seed=st.integers(min_value=0, max_value=1 << 16),
       temperature=st.floats(min_value=0.2, max_value=1.5),
       top_p=st.floats(min_value=0.3, max_value=1.0))
@settings(max_examples=5, deadline=None)
def test_stochastic_trace_invariances(sampling_engines, seed, temperature,
                                      top_p):
    """Stochastic traces are a pure function of (seed, uid, generation
    index): invariant to slot assignment and admission order (4-slot
    engine fed in reverse) and to ``spec_tokens`` (chunked speculative
    engine) — the RNG-ownership contract, for every drawn policy."""
    cfg, base, churn, spec = sampling_engines
    reqs = _requests(cfg, temperature=temperature, top_p=top_p, seed=seed)
    for eng in (base, churn, spec):
        eng.reset_metrics()
    ref = {u: c.tokens for u, c in base.run(
        [dataclasses.replace(r) for r in reqs]).items()}
    got_churn = {u: c.tokens for u, c in churn.run(
        [dataclasses.replace(r) for r in reversed(reqs)]).items()}
    got_spec = {u: c.tokens for u, c in spec.run(
        [dataclasses.replace(r) for r in reqs]).items()}
    assert got_churn == ref
    assert got_spec == ref
    # genuinely stochastic for at least one drawn policy is asserted by
    # test_stochastic_differs_from_greedy below; here only equality.


def test_stochastic_differs_from_greedy(model):
    """Sanity: temperature actually samples (the stochastic lane is not
    dead code) — some request's trace differs from argmax."""
    cfg, params = model
    greedy, _ = _run(cfg, params, _requests(cfg))
    stoch, _ = _run(cfg, params, _requests(cfg, temperature=1.0, seed=5))
    assert greedy != stoch


def test_per_request_seed_changes_trace(model):
    cfg, params = model
    a, _ = _run(cfg, params, _requests(cfg, temperature=1.0, seed=1))
    b, _ = _run(cfg, params, _requests(cfg, temperature=1.0, seed=2))
    assert a != b
    a2, _ = _run(cfg, params, _requests(cfg, temperature=1.0, seed=1))
    assert a == a2                       # deterministic replay


# ---------------------------------------------------------------------------
# 3. Forced extremes via DraftProvider doubles
# ---------------------------------------------------------------------------


def test_all_reject_degenerates_to_baseline(model, greedy_baseline):
    """ConstantDraft(-1): every draft token rejects, so each verify step
    emits exactly one coupled target — the trajectory AND the per-event
    accepted length pin to the baseline one-token step."""
    cfg, params = model
    toks, eng = _run(cfg, params, _requests(cfg), spec_tokens=4,
                     draft=ConstantDraft(-1))
    assert toks == greedy_baseline
    s = eng.stats
    assert s.spec_slot_steps > 0
    assert s.spec_accepted == s.spec_slot_steps      # 1 token per event
    assert s.mean_accepted_len == 1.0


@pytest.mark.parametrize("k", [2, 4])
def test_all_accept_emits_k_per_step(model, k):
    """ReplayDraft of the baseline trace + share_window == k: every
    draft position matches its coupled target and no clamp binds, so
    each verify event emits exactly k tokens — pinning
    ``mean_accepted_len == k`` and the steps_per_s vs tokens_per_s split
    (the PR-8 stats bugfix: one verify step != one token)."""
    cfg, params = model
    cfg_k = dataclasses.replace(
        cfg, h2eal=dataclasses.replace(cfg.h2eal, share_window=k))
    params_k = params
    max_new = 1 + 3 * k                  # prefill token + 3 full chunks
    req = Request(uid=0, prompt=_prompt(cfg, 16, 3), max_new=max_new)
    base, _ = _run(cfg_k, params_k, [dataclasses.replace(req)])
    toks, eng = _run(cfg_k, params_k, [dataclasses.replace(req)],
                     spec_tokens=k, draft=ReplayDraft({0: base[0]}))
    assert toks == base
    s = eng.stats
    assert s.spec_slot_steps == 3
    assert s.spec_accepted == 3 * k
    assert s.mean_accepted_len == k
    assert s.tokens_out == max_new
    # the rate split: tokens and steps share one wall clock, so their
    # ratio is exactly tokens-per-decode-step (> 1 under acceptance)
    assert s.wall_s > 0
    assert s.tokens_per_s / s.steps_per_s == pytest.approx(
        s.tokens_out / s.decode_steps)
    assert s.tokens_out / s.decode_steps > 1.0


def test_streaming_self_draft_lossless(model, greedy_baseline):
    """The self-draft provider (decode body with retrieval masked to
    sink+local) is lossless like any other draft, and its private jits
    compile once."""
    cfg, params = model
    toks, eng = _run(cfg, params, _requests(cfg, n=3), spec_tokens=2,
                     draft="streaming")
    assert toks == {u: greedy_baseline[u] for u in toks}
    sizes = eng.jit_cache_sizes()
    assert sizes["draft_mask"] == 1 and sizes["draft_decode"] == 1, sizes


def test_draft_resolution_and_gates(model):
    cfg, params = model
    assert isinstance(resolve_draft("ngram"), NgramDraft)
    with pytest.raises(ValueError, match="unknown draft"):
        resolve_draft("bogus")
    kw = dict(max_batch=1, capacity=CAP, prompt_buckets=[16])
    with pytest.raises(ValueError, match="h2eal.local"):
        Engine(cfg, params, spec_tokens=cfg.h2eal.local + 1, **kw)
    with pytest.raises(ValueError, match="tiered"):
        Engine(cfg, params, spec_tokens=2, hot_pages=4, **kw)
    hybrid = dataclasses.replace(cfg, mixer_pattern=("mamba2", "attention"))
    with pytest.raises(ValueError, match="all-attention"):
        Engine(hybrid, params, spec_tokens=2, **kw)


def test_ngram_lookup_prefers_longest_suffix():
    d = NgramDraft(max_n=3)
    #          0  1  2  3  4  5  6  7
    hist = [5, 1, 2, 3, 9, 1, 2, 3]
    # suffix (1,2,3) matches at 1..3 -> continuation starts with 9
    assert d._lookup(hist, 2) == [9, 1]
    # no repeat anywhere: pads with the last token
    assert d._lookup([4, 7, 8], 3) == [8, 8, 8]


def test_spec_admission_score_sees_chunk_horizon():
    """sched/balance: under spec_tokens=k a slot one token below a page
    boundary is scored as opening its next page (the verify chunk will
    commit it before the host can rebalance)."""
    from repro.sched import balance

    kw = dict(n_shards=2, page_size=8)
    plain = balance.admission_score([8], 8, **kw)
    spec = balance.admission_score([8], 8, spec_tokens=8, **kw)
    assert plain != spec                  # horizon crossed a page boundary
    assert balance.admission_score([8], 8, spec_tokens=None, **kw) == plain
    assert balance.admission_score([8], 8, spec_tokens=1, **kw) == plain
