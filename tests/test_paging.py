"""Property tests (hypothesis) for the paging invariants.

The central invariant: the [sink | selected | local] sections are
mutually exclusive and, when top-k spans all selectable pages, their
union covers every in-context token exactly once.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import paging

SINK, LOCAL, PAGE = 2, 16, 8


def _mk_state(ctx: int, capacity_pages: int):
    b, h = 1, 1
    page_start = jnp.full((b, h, capacity_pages), -1, jnp.int32)
    n_live = -(-ctx // PAGE)
    starts = jnp.arange(capacity_pages, dtype=jnp.int32) * PAGE
    page_start = jnp.where(jnp.arange(capacity_pages) < n_live, starts, -1)
    return jnp.broadcast_to(page_start, (b, h, capacity_pages))


@settings(deadline=None, max_examples=60)
@given(ctx=st.integers(min_value=1, max_value=400))
def test_partition_complete_and_disjoint(ctx):
    """With top_k = all pages: every token position in [0, ctx) is valid in
    exactly ONE section slot."""
    cap = -(-400 // PAGE) + 2
    page_start = _mk_state(ctx, cap)
    top_k = cap  # select everything selectable
    fake_scores = jnp.ones((1, 1, cap))
    n_sink, _ = paging.page_counts(sink=SINK, local=LOCAL, page=PAGE)
    first_local = max(ctx - LOCAL, 0) // PAGE
    pidx = np.asarray(page_start[0, 0]) // PAGE
    selectable = (np.asarray(page_start[0, 0]) >= 0) & (pidx >= n_sink) & \
        (pidx < first_local)
    masked = jnp.where(jnp.asarray(selectable)[None, None], fake_scores,
                       paging.NEG_INF)
    sel = paging.select_pages(masked, top_k)
    slots = paging.attended_page_slots(sel, jnp.int32(ctx), sink=SINK,
                                       local=LOCAL, page=PAGE)
    valid = paging.token_validity(slots, page_start, jnp.int32(ctx),
                                  sink=SINK, local=LOCAL, page=PAGE,
                                  top_k=top_k)
    # map each valid slot-token back to its absolute position
    slots_np = np.asarray(slots[0, 0])
    starts = np.asarray(page_start[0, 0])
    pos = (starts[np.maximum(slots_np, 0)][:, None]
           + np.arange(PAGE)[None, :]).reshape(-1)
    v = np.asarray(valid[0, 0])
    covered = pos[v]
    # disjoint: no duplicates
    assert len(covered) == len(set(covered.tolist())), (
        f"duplicated positions at ctx={ctx}")
    # complete: all in-context tokens covered
    assert set(covered.tolist()) == set(range(ctx)), (
        f"missing {set(range(ctx)) - set(covered.tolist())} at ctx={ctx}")


@settings(deadline=None, max_examples=30)
@given(ctx=st.integers(min_value=PAGE * 6, max_value=400),
       top_k=st.integers(min_value=1, max_value=8))
def test_sparse_selection_subset(ctx, top_k):
    """With small top_k, valid positions are a subset of full coverage and
    always include sink + local tokens."""
    cap = -(-400 // PAGE) + 2
    page_start = _mk_state(ctx, cap)
    key = jax.random.fold_in(jax.random.PRNGKey(0), ctx)
    raw = jax.random.normal(key, (1, 1, cap))
    n_sink, _ = paging.page_counts(sink=SINK, local=LOCAL, page=PAGE)
    first_local = max(ctx - LOCAL, 0) // PAGE
    pidx = np.asarray(page_start[0, 0]) // PAGE
    selectable = (np.asarray(page_start[0, 0]) >= 0) & (pidx >= n_sink) & \
        (pidx < first_local)
    masked = jnp.where(jnp.asarray(selectable)[None, None], raw,
                       paging.NEG_INF)
    sel = paging.select_pages(masked, top_k)
    slots = paging.attended_page_slots(sel, jnp.int32(ctx), sink=SINK,
                                       local=LOCAL, page=PAGE)
    valid = paging.token_validity(slots, page_start, jnp.int32(ctx),
                                  sink=SINK, local=LOCAL, page=PAGE,
                                  top_k=top_k)
    slots_np = np.asarray(slots[0, 0])
    starts = np.asarray(page_start[0, 0])
    pos = (starts[np.maximum(slots_np, 0)][:, None]
           + np.arange(PAGE)[None, :]).reshape(-1)
    v = np.asarray(valid[0, 0])
    covered = set(pos[v].tolist())
    # no duplicates
    assert len(pos[v]) == len(covered)
    # in-context only
    assert all(0 <= p < ctx for p in covered)
    # sink pages always covered
    for p in range(min(n_sink * PAGE, ctx)):
        assert p in covered, f"sink-page token {p} missing"
    # local window always covered
    for p in range(max(ctx - LOCAL, 0), ctx):
        assert p in covered, f"local token {p} missing (ctx={ctx})"


def test_importance_accumulates_only_live():
    imp = jnp.zeros((1, 1, 4))
    scores = jnp.array([[[1.0, paging.NEG_INF, 2.0, paging.NEG_INF]]])
    out = paging.accumulate_importance(imp, scores)
    np.testing.assert_allclose(np.asarray(out[0, 0]), [1.0, 0.0, 2.0, 0.0])


def test_evict_lowest_skips_dead_pages():
    imp = jnp.array([[[5.0, 1.0, 3.0, 0.1]]])
    page_start = jnp.array([[[0, 8, 16, -1]]])  # last slot dead
    slot = paging.evict_lowest(imp, page_start)
    assert int(slot[0, 0]) == 1  # lowest LIVE importance
