"""End-to-end behaviour tests: training convergence, fault tolerance
(crash + resume exactness), serving consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import H2ealConfig
from repro.data import lm_batch
from repro.models import model as M
from repro.optim import adamw
from repro.runtime import train as train_rt

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return reduced(get_arch("smollm-360m"),
                   num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=256, head_dim=16)


def test_training_reduces_loss():
    cfg = _tiny_cfg()
    tcfg = train_rt.TrainConfig(microbatches=1, remat=False, lr=1e-3,
                                total_steps=40)
    step_fn = jax.jit(train_rt.make_train_step(cfg, tcfg),
                      static_argnums=())
    params = M.init_params(cfg, KEY)
    opt = adamw.init_state(params)
    losses = []
    for s in range(40):
        batch = lm_batch(jnp.int32(s), batch=8, seq=64,
                         vocab=cfg.vocab_size)
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_microbatched_equals_unbatched_gradients():
    """grad accumulation over microbatches == single big batch (same data)."""
    cfg = _tiny_cfg()
    params = M.init_params(cfg, KEY)
    batch = lm_batch(jnp.int32(0), batch=8, seq=32, vocab=cfg.vocab_size)

    def loss_fn(p, t, l):
        return M.lm_loss(cfg, p, t, l, remat=False)

    g_full = jax.grad(loss_fn)(params, batch["tokens"], batch["labels"])
    mb = 4
    tk = batch["tokens"].reshape(mb, 2, 32)
    lb = batch["labels"].reshape(mb, 2, 32)
    g_acc = jax.tree.map(jnp.zeros_like, g_full)
    for i in range(mb):
        g = jax.grad(loss_fn)(params, tk[i], lb[i])
        g_acc = jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda x: x / mb, g_acc)
    flat_f = jax.tree.leaves(g_full)
    flat_a = jax.tree.leaves(g_acc)
    for f, a in zip(flat_f, flat_a):
        np.testing.assert_allclose(np.asarray(f), np.asarray(a), atol=2e-5)


def test_crash_resume_exactness(tmp_path):
    """A crashed-and-resumed run reproduces the uninterrupted run exactly
    (checkpoint + seekable data ⇒ bit-identical trajectory)."""
    from repro.launch import train as train_cli

    d1 = str(tmp_path / "a")
    d2 = str(tmp_path / "b")
    args_common = ["--arch", "smollm-360m", "--reduced", "--steps", "12",
                   "--batch", "4", "--seq", "32", "--ckpt-every", "5",
                   "--log-every", "100"]
    loss_ref = train_cli.main(args_common + ["--ckpt-dir", d1])
    with pytest.raises(RuntimeError, match="injected crash"):
        train_cli.main(args_common + ["--ckpt-dir", d2, "--crash-at", "7"])
    loss_resumed = train_cli.main(args_common + ["--ckpt-dir", d2])
    assert loss_ref == pytest.approx(loss_resumed, abs=1e-6), (
        "resumed trajectory diverged from the uninterrupted run")


def test_serve_generate_h2eal_vs_full_agree_when_dense():
    """With top-k covering everything and all-retrieval heads, H²EAL
    serving produces the same tokens as the full-attention baseline."""
    from repro.launch.serve import generate

    cfg = _tiny_cfg()
    cfg = dataclasses.replace(cfg, h2eal=H2ealConfig(
        sink=2, local=16, page_size=8, select_budget=4096,
        share_window=1, static_sparsity=0.0))
    params = M.init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 40), 0, cfg.vocab_size)
    toks_h, _ = generate(cfg, params, prompts, gen=8, capacity=64)
    toks_f, _ = generate(cfg, params, prompts, gen=8, capacity=64,
                         h2eal=False)
    np.testing.assert_array_equal(np.asarray(toks_h), np.asarray(toks_f))


def test_serve_sparse_h2eal_close_to_full():
    """With realistic sparsity (and an untrained model, so no structure to
    hide behind), the prefill logits of the sparse path must stay highly
    correlated with the full-attention logits — the sparse computation is
    an approximation of the same function, not a different one."""
    cfg = _tiny_cfg()
    # all-retrieval heads: isolates the page-selection approximation (on an
    # untrained model, streaming heads legitimately diverge — the paper's
    # accuracy story relies on trained-in head specialization)
    cfg_sparse = dataclasses.replace(cfg, h2eal=H2ealConfig(
        sink=2, local=16, page_size=8, select_budget=32, share_window=2,
        static_sparsity=0.0))
    cfg_full = dataclasses.replace(cfg, h2eal=H2ealConfig(enabled=False))
    params = M.init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    lg_s, _ = M.prefill(cfg_sparse, params, prompts, capacity=96)
    lg_f, _ = M.prefill(cfg_full, params, prompts, capacity=96)
    a = np.asarray(lg_s, np.float64)
    b = np.asarray(lg_f, np.float64)
    cos = np.sum(a * b, -1) / (np.linalg.norm(a, axis=-1)
                               * np.linalg.norm(b, axis=-1))
    assert np.all(cos > 0.95), f"sparse/full logit cosine {cos}"
