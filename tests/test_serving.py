"""Continuous-batching engine: slot lifecycle, ragged-masking exactness.

The central correctness property (the co-placement exactness check
applied to continuous batching): an active slot's decode trajectory must
be bit-identical whether it runs alone or while other slots join and
leave around it — per-slot lengths, masked appends, and need_select
blending make every cross-slot interaction a no-op.

The same property extends across layouts: the engine under
``layout="coplace_shmap"`` (shard_map partial attention over sharded
pages) must reproduce the default-layout engine's token trace for the
same admission trace (exercised on a host-local multi-device mesh; the
8-fake-device check runs as a slow subprocess test).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine, Request

CAP = 64
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_arch("smollm-360m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)


def test_admission_retirement_lifecycle(model):
    """5 requests through 2 slots: budgets honored, slots recycled,
    nothing recompiles per admission."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16])
    reqs = [Request(uid=i, prompt=_prompt(cfg, 16, i), max_new=2 + i)
            for i in range(5)]
    comps = eng.run(reqs)
    assert sorted(comps) == [0, 1, 2, 3, 4]
    for i, c in comps.items():
        assert len(c.tokens) == 2 + i
        assert c.finished_step >= c.admitted_step
    assert not eng.batch.active.any()
    assert (eng.batch.uid == -1).all()
    assert eng.stats.admissions == 5
    assert eng.stats.prefill_chunks == 0      # packed admission
    assert eng.stats.prefills == 5            # deprecated alias
    # 5 admissions into 2 slots share ONE compile of each decode variant
    sizes = eng.jit_cache_sizes()
    for k in ("decode_select", "decode_reuse", "pack"):
        assert sizes[k] in (-1, 0, 1), sizes
    assert sizes["prefill"] in (-1, 1)


def test_engine_matches_lockstep_single(model):
    """A single request decodes bit-identically to the lockstep driver."""
    from repro.launch.serve import generate

    cfg, params = model
    prompt = _prompt(cfg, 24, 42)
    gen = 10
    toks_lock, _ = generate(cfg, params, jnp.asarray(prompt)[None],
                            gen=gen, capacity=CAP)
    toks_lock = np.asarray(toks_lock)[0].tolist()
    eng = Engine(cfg, params, max_batch=3, capacity=CAP,
                 prompt_buckets=[24])
    comps = eng.run([Request(uid=0, prompt=prompt, max_new=gen)])
    assert comps[0].tokens == toks_lock


def test_active_slot_invariant_to_churn(model):
    """Slot A's tokens are unchanged when B and C join/leave mid-flight."""
    cfg, params = model
    prompt = _prompt(cfg, 24, 42)
    gen = 10
    eng_solo = Engine(cfg, params, max_batch=3, capacity=CAP,
                      prompt_buckets=[24, 16])
    solo = eng_solo.run([Request(uid=0, prompt=prompt, max_new=gen)])
    ref = solo[0].tokens
    assert len(ref) == gen

    eng = Engine(cfg, params, max_batch=3, capacity=CAP,
                 prompt_buckets=[24, 16])
    eng.submit(Request(uid=0, prompt=prompt, max_new=gen))
    steps = 0
    while eng._queue or eng.batch.active.any():
        eng._admit()
        eng.step()
        steps += 1
        if steps == 2:  # B joins mid-flight, retires quickly
            eng.submit(Request(uid=1, prompt=_prompt(cfg, 16, 7),
                               max_new=3))
        if steps == 5:  # C joins as B leaves
            eng.submit(Request(uid=2, prompt=_prompt(cfg, 24, 8),
                               max_new=4))
    eng.finalize()
    assert eng.completions[0].tokens == ref
    assert len(eng.completions[1].tokens) == 3
    assert len(eng.completions[2].tokens) == 4


def test_capacity_truncation(model):
    """A request whose budget exceeds capacity is retired at the cache
    boundary instead of writing out of bounds: the prefill token plus one
    decode per writable position [s, CAP)."""
    cfg, params = model
    s = 16
    eng = Engine(cfg, params, max_batch=1, capacity=CAP,
                 prompt_buckets=[s])
    comps = eng.run([Request(uid=0, prompt=_prompt(cfg, s, 3),
                             max_new=10_000)])
    assert len(comps[0].tokens) == CAP - s + 1
    assert eng.batch.lengths[0] == CAP

    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(uid=1, prompt=_prompt(cfg, s, 4), max_new=0))


def test_no_recompiles_across_arrival_patterns(model):
    """Steady state: a second, differently-shaped workload reuses every
    compiled function (the engine's no-recompile guarantee)."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24])
    eng.run([Request(uid=0, prompt=_prompt(cfg, 16, 0), max_new=4),
             Request(uid=1, prompt=_prompt(cfg, 24, 1), max_new=7)])
    sizes0 = eng.jit_cache_sizes()
    eng.reset_metrics()
    eng.run([Request(uid=10 + i, prompt=_prompt(cfg, [16, 24][i % 2], i),
                     max_new=2 + 3 * i) for i in range(5)])
    assert eng.jit_cache_sizes() == sizes0


def test_select_dispatch_rate_stays_aligned(model):
    """The PR-5 select-dispatch regression, pinned: slots admitted at
    arbitrary times must NOT stagger the batch's refresh phases. READY
    slots join only at a shared refresh boundary (Engine._promote_ready)
    and every slot starts at phase 0, so all active phases share one
    residue mod the share window and the ``select`` decode variant
    dispatches on ~1/w of decode steps — not nearly every step. Each
    slot's own schedule depends only on its own phase, so token traces
    are unchanged (covered by the churn-invariance tests)."""
    cfg, params = model
    w = cfg.h2eal.share_window
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24])
    eng.run([Request(uid=i, prompt=_prompt(cfg, [16, 24][i % 2], i),
                     max_new=12) for i in range(4)])
    s = eng.stats
    assert s.select_steps + s.reuse_steps == s.decode_steps
    # aligned phases: one select per w decode steps, plus at most one
    # boundary re-select per admission batch when a join restarts the
    # residue (staggered phases would push this toward decode_steps)
    assert s.select_steps <= s.decode_steps // w + s.admissions + 1, (
        s.select_steps, s.decode_steps, s.admissions)
    assert s.reuse_steps >= s.decode_steps // 2 - s.admissions - 1, (
        s.reuse_steps, s.decode_steps)


def test_serve_cli_ragged_smoke():
    """launch/serve.py --workload ragged runs on the CPU reduced config."""
    from repro.launch.serve import main

    stats = main([
        "--arch", "smollm-360m", "--reduced", "--workload", "ragged",
        "--requests", "4", "--max-batch", "2", "--prompt-buckets", "16,24",
        "--gen-min", "2", "--gen-max", "6", "--report-balance",
    ])
    assert stats["decode_steps"] > 0
    assert 0.0 < stats["occupancy"] <= 1.0
    assert stats["jit_cache"]["decode_select"] in (-1, 1)
    assert stats["balance"]["imbalance_coplaced"] <= \
        stats["balance"]["imbalance_naive"] + 1e-9


def _mixed_workload(cfg, *, seed=2, n=5):
    """Bucketed prompts + ragged budgets; seed fixed so the greedy token
    traces of the compared engines stay off argmax near-ties (the layouts
    and attention impls differ only in float summation order — the
    exact-tie caveat, documented once in EXPERIMENTS.md §Serving
    experiments)."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=([16, 24][i % 2],)
                                        ).astype(np.int32),
                    max_new=3 + 2 * i)
            for i in range(n)]


def _run_both_layouts(cfg, params):
    """(default completions, coplace_shmap completions) for the same
    admission trace."""
    eng0 = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24])
    c0 = eng0.run(_mixed_workload(cfg))
    eng1 = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24], layout="coplace_shmap")
    c1 = eng1.run(_mixed_workload(cfg))
    return c0, c1, eng1


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="coplace_shmap needs a multi-device host mesh")
def test_engine_coplace_shmap_matches_default(model):
    """Ragged decode under the sharded co-placement layout emits the same
    tokens as the default-layout engine for the same admission trace
    (token-exact off argmax ties; EXPERIMENTS.md §Serving experiments)."""
    cfg, params = model
    c0, c1, eng1 = _run_both_layouts(cfg, params)
    assert sorted(c0) == sorted(c1)
    for uid in sorted(c0):
        assert c0[uid].tokens == c1[uid].tokens, uid
    assert eng1.stats.admissions == len(c1)


def test_engine_attn_impl_pallas_matches_ref(model):
    """Tier-1 pallas-interpret engine parity: the same admission trace
    served with attn impl "pallas" (Pallas kernels, interpret mode on CPU)
    emits exactly the ref engine's tokens, the impl is baked in at
    construction (no extra compiled entries per impl switch — there is no
    impl switch), and unknown impls are rejected. Token-exactness holds
    off argmax ties; see EXPERIMENTS.md §Serving experiments."""
    cfg, params = model
    e_ref = Engine(cfg, params, max_batch=2, capacity=CAP,
                   prompt_buckets=[16, 24], impl="ref")
    c_ref = e_ref.run(_mixed_workload(cfg, n=3))
    e_pal = Engine(cfg, params, max_batch=2, capacity=CAP,
                   prompt_buckets=[16, 24], impl="pallas")
    c_pal = e_pal.run(_mixed_workload(cfg, n=3))
    assert sorted(c_ref) == sorted(c_pal)
    for uid in sorted(c_ref):
        assert c_ref[uid].tokens == c_pal[uid].tokens, uid
    assert e_pal.attn_impl == "pallas"
    with pytest.raises(ValueError, match="valid impls"):
        Engine(cfg, params, max_batch=2, capacity=CAP,
               prompt_buckets=[16], impl="bogus")


COPLACE_ENGINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from tests.test_serving import CAP, _mixed_workload, _run_both_layouts
from repro.serving import Engine, Request

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
c0, c1, eng1 = _run_both_layouts(cfg, params)
assert sorted(c0) == sorted(c1)
for uid in sorted(c0):
    assert c0[uid].tokens == c1[uid].tokens, (
        uid, c0[uid].tokens, c1[uid].tokens)
# steady state must also hold sharded: a second differently-shaped
# workload reuses every compiled entry (no post-warmup recompiles)
sizes0 = eng1.jit_cache_sizes()
eng1.reset_metrics()
eng1.run(_mixed_workload(cfg, seed=5, n=4))
assert eng1.jit_cache_sizes() == sizes0, (sizes0, eng1.jit_cache_sizes())
print("COPLACE_ENGINE_EXACT")
"""


@pytest.mark.slow
def test_engine_coplace_shmap_exact_8dev():
    """8-fake-device subprocess: the coplace_shmap engine's ragged decode
    is token-exact vs the default-layout engine and never recompiles
    after warmup."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", COPLACE_ENGINE_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "COPLACE_ENGINE_EXACT" in out.stdout


INTERLEAVE_ENGINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.tree_util as jtu
from jax.sharding import PartitionSpec as P
from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.runtime.compat import make_mesh
from tests.test_serving import CAP, _mixed_workload
from repro.serving import Engine

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
eng0 = Engine(cfg, params, max_batch=2, capacity=CAP,
              prompt_buckets=[16, 24])
c0 = eng0.run(_mixed_workload(cfg))
# pages -> 'model' AND within-page tokens -> 'data': max_batch=2 cannot
# consume data=4, so the pages leaves genuinely stripe within-page tokens
mesh = make_mesh((4, 2), ("data", "model"))
eng1 = Engine(cfg, params, max_batch=2, capacity=CAP,
              prompt_buckets=[16, 24], layout="interleave", mesh=mesh,
              admission="balanced")
ss = eng1.plan.state_shardings(cfg, eng1.batch.serve, batch_size=2)
pages = [s.spec for p, s in jtu.tree_flatten_with_path(ss)[0]
         if "k_pages" in jtu.keystr(p)]
assert pages and all(
    sp == P(None, None, None, "model", "data", None) for sp in pages), pages
c1 = eng1.run(_mixed_workload(cfg))
assert sorted(c0) == sorted(c1)
for uid in sorted(c0):
    assert c0[uid].tokens == c1[uid].tokens, (
        uid, c0[uid].tokens, c1[uid].tokens)
# steady state must also hold sharded: a second differently-shaped
# workload reuses every compiled entry (no post-warmup recompiles)
sizes0 = eng1.jit_cache_sizes()
eng1.reset_metrics()
eng1.run(_mixed_workload(cfg, seed=5, n=4))
assert eng1.jit_cache_sizes() == sizes0, (sizes0, eng1.jit_cache_sizes())
print("INTERLEAVE_ENGINE_EXACT")
"""


@pytest.mark.slow
def test_engine_interleave_exact_8dev():
    """8-fake-device subprocess (the ISSUE-4 acceptance check): ragged
    decode under the GSPMD ``interleave`` layout (pages over 'model',
    within-page tokens striped over 'data') is token-exact vs the
    default-layout engine for the same admission trace, with zero
    post-warmup recompiles — served purely through the core/layouts
    registry entry (no interleave-specific engine code)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", INTERLEAVE_ENGINE_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "INTERLEAVE_ENGINE_EXACT" in out.stdout


PALLAS_ENGINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from tests.test_serving import CAP, _mixed_workload
from repro.serving import Engine

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
engines = {}
for impl in ("ref", "pallas"):
    engines[impl] = Engine(cfg, params, max_batch=2, capacity=CAP,
                           prompt_buckets=[16, 24],
                           layout="coplace_shmap", impl=impl)
comps = {impl: eng.run(_mixed_workload(cfg, n=4))
         for impl, eng in engines.items()}
assert sorted(comps["ref"]) == sorted(comps["pallas"])
for uid in sorted(comps["ref"]):
    assert comps["ref"][uid].tokens == comps["pallas"][uid].tokens, (
        uid, comps["ref"][uid].tokens, comps["pallas"][uid].tokens)
# the pallas engine must hold the zero-recompile invariant too: a second
# differently-shaped workload reuses every compiled entry
eng = engines["pallas"]
sizes0 = eng.jit_cache_sizes()
eng.reset_metrics()
eng.run(_mixed_workload(cfg, seed=5, n=3))
assert eng.jit_cache_sizes() == sizes0, (sizes0, eng.jit_cache_sizes())
print("PALLAS_ENGINE_EXACT")
"""


@pytest.mark.slow
def test_engine_coplace_shmap_pallas_exact_8dev():
    """8-fake-device subprocess (the ISSUE-3 acceptance check): engine
    decode with attn impl "pallas" (Pallas partial attention + fused
    combine, interpret mode) under coplace_shmap is token-exact vs
    impl "ref" for the same admission trace, with zero post-warmup
    recompiles."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", PALLAS_ENGINE_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "PALLAS_ENGINE_EXACT" in out.stdout


# ---------------------------------------------------------------------------
# Chunked (slot-resident) prefill — ISSUE 5
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_packed_with_churn(model):
    """Chunked admission is token-exact vs prefill-then-pack for the same
    admission trace, across chunk sizes, prompt lengths, and slot churn
    (off argmax ties; EXPERIMENTS.md §Serving experiments). Also pins the
    zero-recompile invariant: one compiled chunk program serves every
    chunk schedule, including prompt lengths outside the buckets."""
    cfg, params = model
    eng0 = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24])
    ref = {u: c.tokens for u, c in eng0.run(_mixed_workload(cfg)).items()}
    for chunk in (3, 8, 64):
        eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                     prompt_buckets=[16, 24], prefill_chunk=chunk)
        got = eng.run(_mixed_workload(cfg))
        assert sorted(got) == sorted(ref), chunk
        for uid in sorted(ref):
            assert got[uid].tokens == ref[uid], (chunk, uid)
        assert eng.stats.admissions == len(ref)
        assert eng.stats.prefill_chunks > 0
        sizes0 = eng.jit_cache_sizes()
        assert sizes0["prefill_chunk"] in (-1, 1)
        assert sizes0["prefill"] in (-1, 0)       # pack path never used
        # non-bucket prompt lengths reuse the same compiled chunk fn
        eng.reset_metrics()
        rng = np.random.default_rng(chunk)
        eng.run([Request(uid=90 + i, prompt=_prompt(cfg, 5 + 7 * i, i),
                         max_new=2 + i) for i in range(3)])
        assert eng.jit_cache_sizes() == sizes0, chunk


def test_chunked_prefill_property_chunk_x_prompt(model):
    """Hypothesis-compat property: for any chunk size and prompt length,
    feeding the prompt through M.prefill_chunk (against a reset slot of
    the batched state) reproduces the single-shot M.prefill: same greedy
    first token, logits to float tolerance, and identical KV caches up
    to reassociation-level float error."""
    from tests._hypothesis_compat import given, settings, st

    cfg, params = model
    from repro.runtime import serve as serve_rt
    from repro.serving.engine import _reset_slot

    scfg = serve_rt.ServeConfig(capacity=CAP)
    prefill = jax.jit(serve_rt.make_prefill(cfg, scfg))

    @settings(max_examples=5)
    @given(chunk=st.integers(min_value=1, max_value=40),
           plen=st.integers(min_value=4, max_value=30))
    def check(chunk, plen):
        prompt = _prompt(cfg, plen, seed=chunk * 100 + plen)
        logits1, packed = prefill(params, jnp.asarray(prompt)[None])
        # empty batch-1 state with the reset sentinels, grown chunk-wise
        shapes = jax.eval_shape(prefill, params, prompt[None])[1]
        state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        state["length"] = jnp.zeros((1,), jnp.int32)
        state = _reset_slot(state, jnp.int32(0))
        step = jax.jit(serve_rt.make_prefill_chunk_step(cfg, scfg,
                                                        chunk=chunk))
        logits2 = None
        for lo in range(0, plen, chunk):
            n = min(chunk, plen - lo)
            toks = np.zeros((1, chunk), np.int32)
            toks[0, :n] = prompt[lo:lo + n]
            logits2, state = step(params, state, jnp.asarray(toks),
                                  jnp.asarray([n], np.int32),
                                  jnp.asarray([True]))
        assert int(state["length"][0]) == plen
        np.testing.assert_allclose(np.asarray(logits2[0]),
                                   np.asarray(logits1[0]),
                                   rtol=2e-4, atol=2e-4)
        assert int(jnp.argmax(logits2[0])) == int(jnp.argmax(logits1[0]))
        # cache equivalence: packed state is scalar-length batch-1; the
        # chunked state must hold the same KV (float tolerance), same
        # page bookkeeping, and the same stream ring occupancy
        import jax.tree_util as jtu
        flat1 = jtu.tree_flatten_with_path(packed)[0]
        flat2 = jtu.tree_flatten_with_path(state)[0]
        for (p1, a), (p2, b) in zip(flat1, flat2):
            ps = jtu.keystr(p1)
            assert ps == jtu.keystr(p2)
            if "length" in ps or "sel_idx" in ps or "importance" in ps:
                continue
            a, b = np.asarray(a), np.asarray(b)
            if a.dtype.kind == "f":
                fin = np.isfinite(a)
                assert (fin == np.isfinite(b)).all(), ps
                np.testing.assert_allclose(b[fin], a[fin], rtol=2e-4,
                                           atol=2e-4, err_msg=ps)
            else:
                np.testing.assert_array_equal(a, b, err_msg=ps)

    check()


def test_chunked_decode_continues_during_long_prefill(model):
    """The no-head-of-line acceptance property, step-exact: while a
    max-bucket prompt chunk-prefills over several engine steps, a
    concurrently decoding slot emits one token per engine step. Under
    prefill-then-pack the same admission is atomic — zero tokens emitted
    between the long request's admission and its first token."""
    cfg, params = model

    def serve(prefill_chunk):
        eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                     prompt_buckets=[16, 24],
                     prefill_chunk=prefill_chunk)
        eng.submit(Request(uid=0, prompt=_prompt(cfg, 16, 1), max_new=30))
        steps = 0
        while eng.busy():
            if steps == 2:   # long prompt arrives while uid 0 decodes
                eng.submit(Request(uid=1, prompt=_prompt(cfg, 24, 2),
                                   max_new=3))
            eng.poll()
            steps += 1
        eng.finalize()
        long_c = eng.completions[1]
        other = eng.completions[0]
        during = sum(
            1 for es in eng.token_engine_steps(other)
            if long_c.admitted_engine_step < es < long_c.first_token_step)
        return eng, during

    eng_c, during_c = serve(prefill_chunk=6)
    eng_p, during_p = serve(prefill_chunk=None)
    # chunked: ceil(24/6) = 4 chunk steps; decode ran in every one of the
    # strictly-between steps. packed: admission is atomic — none.
    assert during_c >= 2, during_c
    assert during_p == 0, during_p
    assert eng_c.completions[1].tokens == eng_p.completions[1].tokens
    assert eng_c.completions[0].tokens == eng_p.completions[0].tokens
    assert eng_c.stats.prefill_chunks >= 4


def test_chunked_prefill_validation(model):
    """Chunked mode rejects what it cannot serve, at construction or
    submit time: frontend-stub archs (no token prompts to chunk) and
    prompts that leave no room to decode. Bucket membership is NOT
    required (chunk compiles are per chunk bucket, not per prompt
    bucket), and recurrent mixers are NOT rejected — they resume their
    per-slot scan state across chunk boundaries (the ISSUE-6 refactor
    deleted the attention-only restriction)."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=1, capacity=CAP,
                 prompt_buckets=[16], prefill_chunk=4)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(Request(uid=0, prompt=_prompt(cfg, CAP, 0), max_new=1))
    comps = eng.run([Request(uid=1, prompt=_prompt(cfg, 13, 1), max_new=2)])
    assert len(comps[1].tokens) == 2          # non-bucket length is fine

    vcfg = reduced(get_arch("internvl2-1b"))  # frontend-stub (vlm)
    vparams = M.init_params(vcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="frontend-stub"):
        Engine(vcfg, vparams, max_batch=1, capacity=CAP,
               prompt_buckets=[16], prefill_chunk=4)
    # packed admission for the same arch still constructs
    Engine(vcfg, vparams, max_batch=1, capacity=CAP, prompt_buckets=[16])

    zcfg = reduced(get_arch("zamba2-2.7b"))   # mamba2 mixers: now served
    zparams = M.init_params(zcfg, jax.random.PRNGKey(0))
    zeng = Engine(zcfg, zparams, max_batch=1, capacity=CAP,
                  prompt_buckets=[16], prefill_chunk=4)
    zc = zeng.run([Request(uid=0, prompt=_prompt(zcfg, 11, 5), max_new=3)])
    assert len(zc[0].tokens) == 3
    assert zeng.stats.prefill_chunks == 3     # ceil(11/4)


def _recurrent_cfgs():
    """(name, cfg) rows covering every recurrent mixer kind plus a
    hybrid that interleaves attention and mamba2 blocks."""
    return [
        ("mamba2", reduced(get_arch("zamba2-2.7b"))),
        ("xlstm", reduced(get_arch("xlstm-125m"))),
        ("hybrid", reduced(get_arch("zamba2-2.7b"),
                           mixer_pattern=("mamba2", "mamba2", "attention"),
                           num_layers=3)),
    ]


@pytest.mark.parametrize("name,cfg",
                         _recurrent_cfgs(),
                         ids=[n for n, _ in _recurrent_cfgs()])
def test_chunked_prefill_recurrent_matches_packed(name, cfg):
    """ISSUE-6 acceptance: chunked admission over recurrent mixers
    (mamba2 SSD scan, mlstm/slstm, and an attention+mamba2 hybrid) is
    token-exact vs prefill-then-pack at chunks {1, 8, 64}, with slot
    churn and zero post-warmup recompiles — per-slot scan state resumes
    across chunk boundaries and decode-state freezing protects slots
    that are mid-prefill while others decode."""
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng0 = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24])
    ref = {u: c.tokens
           for u, c in eng0.run(_mixed_workload(cfg, n=4)).items()}
    for chunk in (1, 8, 64):
        eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                     prompt_buckets=[16, 24], prefill_chunk=chunk)
        got = eng.run(_mixed_workload(cfg, n=4))
        assert sorted(got) == sorted(ref), (name, chunk)
        for uid in sorted(ref):
            assert got[uid].tokens == ref[uid], (name, chunk, uid)
        assert eng.stats.prefill_chunks > 0
        sizes0 = eng.jit_cache_sizes()
        eng.reset_metrics()
        eng.run(_mixed_workload(cfg, seed=9, n=2))
        assert eng.jit_cache_sizes() == sizes0, (name, chunk)


CHUNKED_ENGINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from tests.test_serving import CAP, _mixed_workload
from repro.serving import Engine

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
eng0 = Engine(cfg, params, max_batch=2, capacity=CAP,
              prompt_buckets=[16, 24])
c0 = eng0.run(_mixed_workload(cfg))
for layout in ("coplace_shmap", "interleave"):
    eng1 = Engine(cfg, params, max_batch=2, capacity=CAP,
                  prompt_buckets=[16, 24], layout=layout,
                  admission="balanced", prefill_chunk=7)
    c1 = eng1.run(_mixed_workload(cfg))
    assert sorted(c0) == sorted(c1), layout
    for uid in sorted(c0):
        assert c0[uid].tokens == c1[uid].tokens, (
            layout, uid, c0[uid].tokens, c1[uid].tokens)
    assert eng1.stats.prefill_chunks > 0
    # zero post-warmup recompiles across mixed prefill+decode steps
    sizes0 = eng1.jit_cache_sizes()
    eng1.reset_metrics()
    eng1.run(_mixed_workload(cfg, seed=5, n=4))
    assert eng1.jit_cache_sizes() == sizes0, (
        layout, sizes0, eng1.jit_cache_sizes())
    print("CHUNKED_ENGINE_EXACT", layout)
"""


@pytest.mark.slow
def test_engine_chunked_sharded_exact_8dev():
    """8-fake-device subprocess (the ISSUE-5 acceptance check): chunked
    slot-resident prefill under BOTH sharded layouts (coplace_shmap
    shard_map co-placement and GSPMD interleave) is token-exact vs the
    default-layout prefill-then-pack engine for the same admission
    trace, with zero post-warmup recompiles across mixed prefill+decode
    steps — the prompt KV streams directly into the sharded paged cache
    through the layout protocol."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", CHUNKED_ENGINE_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert out.stdout.count("CHUNKED_ENGINE_EXACT") == 2


CHUNKED_PALLAS_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from tests.test_serving import CAP, _mixed_workload
from repro.serving import Engine

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
comps = {}
for impl in ("ref", "pallas"):
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], layout="coplace_shmap",
                 impl=impl, prefill_chunk=7)
    comps[impl] = eng.run(_mixed_workload(cfg, n=4))
    assert eng.stats.prefill_chunks > 0, impl
assert sorted(comps["ref"]) == sorted(comps["pallas"])
for uid in sorted(comps["ref"]):
    assert comps["ref"][uid].tokens == comps["pallas"][uid].tokens, (
        uid, comps["ref"][uid].tokens, comps["pallas"][uid].tokens)
# the chunked pallas engine must hold the zero-recompile invariant too
sizes0 = eng.jit_cache_sizes()
eng.reset_metrics()
eng.run(_mixed_workload(cfg, seed=5, n=3))
assert eng.jit_cache_sizes() == sizes0, (sizes0, eng.jit_cache_sizes())
# chunked recurrent state lives in the sharded batched pytree: a hybrid
# attention+mamba2 config serves chunked on the same 8-device mesh and
# matches its own packed trace token-for-token
hcfg = reduced(get_arch("zamba2-2.7b"),
               mixer_pattern=("mamba2", "mamba2", "attention"),
               num_layers=3)
hparams = M.init_params(hcfg, jax.random.PRNGKey(0))
h0 = Engine(hcfg, hparams, max_batch=2, capacity=CAP,
            prompt_buckets=[16, 24]).run(_mixed_workload(hcfg, n=4))
h1 = Engine(hcfg, hparams, max_batch=2, capacity=CAP,
            prompt_buckets=[16, 24],
            prefill_chunk=7).run(_mixed_workload(hcfg, n=4))
for uid in sorted(h0):
    assert h0[uid].tokens == h1[uid].tokens, uid
print("CHUNKED_PALLAS_EXACT")
"""


@pytest.mark.slow
def test_engine_chunked_pallas_exact_8dev():
    """8-fake-device subprocess (the ISSUE-6 acceptance check): chunked
    prefill through ops.chunk_attention / ops.chunk_attention_paged with
    impl "pallas" (interpret mode) under coplace_shmap is token-exact vs
    impl "ref" for the same admission trace, with zero post-warmup
    recompiles; a hybrid attention+mamba2 config chunk-prefills on the
    same mesh and matches its packed trace."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", CHUNKED_PALLAS_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "CHUNKED_PALLAS_EXACT" in out.stdout


def test_balanced_admission_reorders(model):
    """admission="balanced" admits the queued request that flattens the
    per-device page load (sched/balance.admission_score) and still serves
    every request exactly once."""
    from repro.sched import admission_score, device_page_loads

    cfg, params = model
    p = cfg.h2eal.page_size
    # direct scoring: with 4 shards and 3 live pages, a 1-page candidate
    # lands on the already-loaded shard 0; a 5-page candidate wraps and
    # fills shard 3 — the flatter choice must score lower.
    assert device_page_loads([3 * p], n_shards=4, page_size=p) == [1, 1, 1, 0]
    tight = admission_score([3 * p], 5 * p, n_shards=4, page_size=p)
    loose = admission_score([3 * p], 1 * p, n_shards=4, page_size=p)
    assert tight < loose

    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24], admission="balanced",
                 balance_shards=4)
    comps = eng.run(_mixed_workload(cfg, seed=7, n=6))
    # the 16/24-token buckets produce different page remainders mod 4
    # shards, so at least one admission must deviate from FIFO
    assert eng.stats.admission_reorders > 0
    assert sorted(comps) == list(range(6))
    for i, c in comps.items():
        assert len(c.tokens) == 3 + 2 * i
    # FIFO engine on the same workload serves the same completions
    eng_f = Engine(cfg, params, max_batch=2, capacity=CAP,
                   prompt_buckets=[16, 24])
    comps_f = eng_f.run(_mixed_workload(cfg, seed=7, n=6))
    assert sorted(comps_f) == sorted(comps)


def test_slot_lpt_mapping():
    """map_slots: greedy LPT flattens whole-slot placement; imbalance is
    never worse than naive round-robin and totals are conserved."""
    from repro.sched import load_imbalance, map_slots

    loads = [40.0, 3.0, 29.0, 10.0, 12.0, 5.0]
    a = map_slots(loads, 3)
    assert sorted(s for bank in a.banks for s in bank) == list(range(6))
    assert sum(a.loads) == pytest.approx(sum(loads))
    rr = [sum(loads[i] for i in range(len(loads)) if i % 3 == b)
          for b in range(3)]
    assert a.imbalance <= load_imbalance(rr) + 1e-9
    # the 40-load slot alone pins the optimum at 40/33; LPT attains it
    assert a.imbalance == pytest.approx(40.0 / 33.0)


def test_ragged_balance_scoring():
    """sched/balance scores a ragged batch: loads cap at each slot's
    context, co-placement splits exactly, totals are conserved."""
    from repro.configs.base import H2ealConfig
    from repro.sched import (grid_coords, imbalance, occupancy,
                             ragged_loads, slot_head_load, solve_tiling)

    h2 = H2ealConfig()  # sink=4 local=256 select_budget=4096
    # short context: every head is capped at ctx tokens
    assert slot_head_load("streaming", h2, 17) == 17
    assert slot_head_load("retrieval", h2, 17) == pytest.approx(
        17 + 2.0 * 1 / h2.page_size)
    # long context: streaming saturates, retrieval pays the metadata scan
    assert slot_head_load("streaming", h2, 100_000) == h2.sink + h2.local
    long_r = slot_head_load("retrieval", h2, 100_000)
    assert long_r > h2.sink + h2.local + h2.select_budget

    coords = grid_coords(4, 4)
    retr, stream = coords[:4], coords[4:]
    tiles, _ = solve_tiling(retr, stream)
    kinds = {c: ("retrieval" if c in retr else "streaming") for c in coords}
    ctx = [17, 300, 5_000, 100_000]  # a properly ragged batch
    u = ragged_loads(tiles, kinds, h2, ctx, balanced=False)
    b = ragged_loads(tiles, kinds, h2, ctx, balanced=True)
    assert imbalance(b) < 1.01 < imbalance(u)
    assert sum(x.load for x in u) == pytest.approx(sum(x.load for x in b))
    assert occupancy([True, False, True, False]) == 0.5
