"""Continuous-batching engine: slot lifecycle, ragged-masking exactness.

The central correctness property (the co-placement exactness check
applied to continuous batching): an active slot's decode trajectory must
be bit-identical whether it runs alone or while other slots join and
leave around it — per-slot lengths, masked appends, and need_select
blending make every cross-slot interaction a no-op.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine, Request

CAP = 64


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_arch("smollm-360m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)


def test_admission_retirement_lifecycle(model):
    """5 requests through 2 slots: budgets honored, slots recycled,
    nothing recompiles per admission."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16])
    reqs = [Request(uid=i, prompt=_prompt(cfg, 16, i), max_new=2 + i)
            for i in range(5)]
    comps = eng.run(reqs)
    assert sorted(comps) == [0, 1, 2, 3, 4]
    for i, c in comps.items():
        assert len(c.tokens) == 2 + i
        assert c.finished_step >= c.admitted_step
    assert not eng.batch.active.any()
    assert (eng.batch.uid == -1).all()
    assert eng.stats.prefills == 5
    # 5 admissions into 2 slots share ONE compile of each decode variant
    sizes = eng.jit_cache_sizes()
    for k in ("decode_select", "decode_reuse", "pack"):
        assert sizes[k] in (-1, 0, 1), sizes
    assert sizes["prefill"] in (-1, 1)


def test_engine_matches_lockstep_single(model):
    """A single request decodes bit-identically to the lockstep driver."""
    from repro.launch.serve import generate

    cfg, params = model
    prompt = _prompt(cfg, 24, 42)
    gen = 10
    toks_lock, _ = generate(cfg, params, jnp.asarray(prompt)[None],
                            gen=gen, capacity=CAP)
    toks_lock = np.asarray(toks_lock)[0].tolist()
    eng = Engine(cfg, params, max_batch=3, capacity=CAP,
                 prompt_buckets=[24])
    comps = eng.run([Request(uid=0, prompt=prompt, max_new=gen)])
    assert comps[0].tokens == toks_lock


def test_active_slot_invariant_to_churn(model):
    """Slot A's tokens are unchanged when B and C join/leave mid-flight."""
    cfg, params = model
    prompt = _prompt(cfg, 24, 42)
    gen = 10
    eng_solo = Engine(cfg, params, max_batch=3, capacity=CAP,
                      prompt_buckets=[24, 16])
    solo = eng_solo.run([Request(uid=0, prompt=prompt, max_new=gen)])
    ref = solo[0].tokens
    assert len(ref) == gen

    eng = Engine(cfg, params, max_batch=3, capacity=CAP,
                 prompt_buckets=[24, 16])
    eng.submit(Request(uid=0, prompt=prompt, max_new=gen))
    steps = 0
    while eng._queue or eng.batch.active.any():
        eng._admit()
        eng.step()
        steps += 1
        if steps == 2:  # B joins mid-flight, retires quickly
            eng.submit(Request(uid=1, prompt=_prompt(cfg, 16, 7),
                               max_new=3))
        if steps == 5:  # C joins as B leaves
            eng.submit(Request(uid=2, prompt=_prompt(cfg, 24, 8),
                               max_new=4))
    eng.finalize()
    assert eng.completions[0].tokens == ref
    assert len(eng.completions[1].tokens) == 3
    assert len(eng.completions[2].tokens) == 4


def test_capacity_truncation(model):
    """A request whose budget exceeds capacity is retired at the cache
    boundary instead of writing out of bounds: the prefill token plus one
    decode per writable position [s, CAP)."""
    cfg, params = model
    s = 16
    eng = Engine(cfg, params, max_batch=1, capacity=CAP,
                 prompt_buckets=[s])
    comps = eng.run([Request(uid=0, prompt=_prompt(cfg, s, 3),
                             max_new=10_000)])
    assert len(comps[0].tokens) == CAP - s + 1
    assert eng.batch.lengths[0] == CAP

    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(uid=1, prompt=_prompt(cfg, s, 4), max_new=0))


def test_no_recompiles_across_arrival_patterns(model):
    """Steady state: a second, differently-shaped workload reuses every
    compiled function (the engine's no-recompile guarantee)."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=2, capacity=CAP,
                 prompt_buckets=[16, 24])
    eng.run([Request(uid=0, prompt=_prompt(cfg, 16, 0), max_new=4),
             Request(uid=1, prompt=_prompt(cfg, 24, 1), max_new=7)])
    sizes0 = eng.jit_cache_sizes()
    eng.reset_metrics()
    eng.run([Request(uid=10 + i, prompt=_prompt(cfg, [16, 24][i % 2], i),
                     max_new=2 + 3 * i) for i in range(5)])
    assert eng.jit_cache_sizes() == sizes0


def test_serve_cli_ragged_smoke():
    """launch/serve.py --workload ragged runs on the CPU reduced config."""
    from repro.launch.serve import main

    stats = main([
        "--arch", "smollm-360m", "--reduced", "--workload", "ragged",
        "--requests", "4", "--max-batch", "2", "--prompt-buckets", "16,24",
        "--gen-min", "2", "--gen-max", "6", "--report-balance",
    ])
    assert stats["decode_steps"] > 0
    assert 0.0 < stats["occupancy"] <= 1.0
    assert stats["jit_cache"]["decode_select"] in (-1, 1)
    assert stats["balance"]["imbalance_coplaced"] <= \
        stats["balance"]["imbalance_naive"] + 1e-9


def test_ragged_balance_scoring():
    """sched/balance scores a ragged batch: loads cap at each slot's
    context, co-placement splits exactly, totals are conserved."""
    from repro.configs.base import H2ealConfig
    from repro.sched import (grid_coords, imbalance, occupancy,
                             ragged_loads, slot_head_load, solve_tiling)

    h2 = H2ealConfig()  # sink=4 local=256 select_budget=4096
    # short context: every head is capped at ctx tokens
    assert slot_head_load("streaming", h2, 17) == 17
    assert slot_head_load("retrieval", h2, 17) == pytest.approx(
        17 + 2.0 * 1 / h2.page_size)
    # long context: streaming saturates, retrieval pays the metadata scan
    assert slot_head_load("streaming", h2, 100_000) == h2.sink + h2.local
    long_r = slot_head_load("retrieval", h2, 100_000)
    assert long_r > h2.sink + h2.local + h2.select_budget

    coords = grid_coords(4, 4)
    retr, stream = coords[:4], coords[4:]
    tiles, _ = solve_tiling(retr, stream)
    kinds = {c: ("retrieval" if c in retr else "streaming") for c in coords}
    ctx = [17, 300, 5_000, 100_000]  # a properly ragged batch
    u = ragged_loads(tiles, kinds, h2, ctx, balanced=False)
    b = ragged_loads(tiles, kinds, h2, ctx, balanced=True)
    assert imbalance(b) < 1.01 < imbalance(u)
    assert sum(x.load for x in u) == pytest.approx(sum(x.load for x in b))
    assert occupancy([True, False, True, False]) == 0.5
