"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle.

These parity tests are tier-1 (never behind the ``slow`` marker) so
CPU-only CI always exercises the Pallas kernel path — see scripts/ci.sh.
Tolerance bands are documented in EXPERIMENTS.md §Serving experiments.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.page_score import page_score
from repro.kernels.paged_attention import (combine_partials, paged_attention,
                                           paged_attention_partial)

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


FLASH_CASES = [
    # b, sq, sk, hq, hkv, d, causal, window, sink
    (2, 256, 256, 4, 2, 64, True, 0, 0),
    (1, 128, 128, 4, 4, 64, True, 64, 4),
    (2, 200, 200, 6, 2, 32, True, 0, 0),       # non-block-multiple
    (1, 256, 256, 2, 1, 128, False, 0, 0),     # non-causal, MQA
    (1, 96, 96, 3, 1, 80, True, 32, 2),        # odd head_dim
    (1, 384, 384, 8, 8, 256, True, 0, 0),      # MHA, big head_dim
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, sq, sk, hq, hkv, d, causal, window, sink = case
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, sq, hq, d), dtype)
    k = _rand(ks[1], (b, sk, hkv, d), dtype)
    v = _rand(ks[2], (b, sk, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, sink=sink,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                  sink=sink)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


PAGED_CASES = [
    (2, 8, 2, 640, 64),
    (1, 4, 4, 500, 128),   # non-block-multiple T
    (2, 2, 1, 100, 32),
    (1, 16, 2, 1024, 64),  # large GQA group
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(case, dtype):
    b, hq, hkv, t, d = case
    ks = jax.random.split(KEY, 4)
    q = _rand(ks[0], (b, hq, d), dtype)
    k = _rand(ks[1], (b, hkv, t, d), dtype)
    v = _rand(ks[2], (b, hkv, t, d), dtype)
    valid = jax.random.bernoulli(ks[3], 0.7, (b, hkv, t))
    out = paged_attention(q, k, v, valid, interpret=True)
    exp = ref.paged_attention_ref(q, k, v, valid)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


def test_paged_attention_all_invalid_is_zero():
    b, hq, hkv, t, d = 1, 4, 2, 64, 32
    q = _rand(KEY, (b, hq, d), jnp.float32)
    k = jnp.ones((b, hkv, t, d))
    v = jnp.ones((b, hkv, t, d))
    valid = jnp.zeros((b, hkv, t), bool)
    out = paged_attention(q, k, v, valid, interpret=True)
    assert np.all(np.asarray(out) == 0.0)


SCORE_CASES = [
    (2, 8, 2, 300, 64),
    (1, 4, 1, 1000, 128),
    (2, 6, 3, 64, 32),
    (1, 4, 4, 37, 16),     # tiny, non-aligned
]


@pytest.mark.parametrize("case", SCORE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_page_score_matches_ref(case, dtype):
    b, hq, hkv, c, d = case
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, hq, d), dtype)
    tn = _rand(ks[1], (b, hkv, c, d), jnp.float32) - 1.0
    tx = tn + jnp.abs(_rand(ks[2], (b, hkv, c, d), jnp.float32))
    out = page_score(q, tn, tx, interpret=True)
    exp = ref.page_score_ref(q, tn, tx)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=tol, rtol=tol)


def test_page_score_is_upper_bound():
    """max(q·τmin, q·τmax) ≥ q·k for every key in the page (the Quest
    guarantee that makes top-k selection sound)."""
    ks = jax.random.split(KEY, 2)
    keys = jax.random.normal(ks[0], (1, 2, 16, 8, 32))  # (B,H,pages,P,D)
    q = jax.random.normal(ks[1], (1, 4, 32))
    tn = keys.min(axis=3)
    tx = keys.max(axis=3)
    scores = ref.page_score_ref(q, tn, tx)  # (1, 2, 16)
    group = 2
    qg = np.asarray(q).reshape(1, 2, group, 32)
    per_key = np.einsum("bhgd,bhpkd->bhgpk", qg, np.asarray(keys))
    per_key_groupsum = per_key.sum(axis=2)  # (b, h, p, k)
    assert np.all(np.asarray(scores)[..., None] >= per_key_groupsum - 1e-4)


PARTIAL_CASES = [
    (2, 8, 2, 640, 64),
    (1, 4, 4, 500, 128),   # non-block-multiple T, MHA
    (2, 2, 1, 100, 32),    # MQA
    (1, 16, 2, 1024, 64),  # large GQA group
]


@pytest.mark.parametrize("case", PARTIAL_CASES)
@pytest.mark.parametrize("density", [0.0, 0.4, 1.0])
def test_paged_attention_partial_matches_ref(case, density):
    """Pallas partial decode attention (interpret) vs the pure-jnp oracle
    over ragged validity masks — the (m, l, o) shape contract of
    kernels.ref.paged_attention_partial_ref, tolerance band in
    EXPERIMENTS.md §Serving experiments. density=0.0 is the all-invalid
    identity (m=NEG_INF, l=0, o=0) every retired slot/empty shard hits."""
    b, hq, hkv, t, d = case
    ks = jax.random.split(jax.random.fold_in(KEY, int(density * 10)), 4)
    q = _rand(ks[0], (b, hq, d), jnp.float32)
    k = _rand(ks[1], (b, hkv, t, d), jnp.float32)
    v = _rand(ks[2], (b, hkv, t, d), jnp.float32)
    valid = jax.random.bernoulli(ks[3], density, (b, hkv, t))
    m, l, o = paged_attention_partial(q, k, v, valid, interpret=True)
    me, le, oe = ref.paged_attention_partial_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(m), np.asarray(me),
                               atol=2e-6, rtol=2e-6)
    np.testing.assert_allclose(np.asarray(l), np.asarray(le),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oe),
                               atol=2e-5, rtol=2e-5)
    if density == 0.0:
        assert np.all(np.asarray(m) == ref.NEG_INF)
        assert np.all(np.asarray(l) == 0.0)
        assert np.all(np.asarray(o) == 0.0)


def test_combine_partials_kernel_matches_ref():
    """Fused combine epilogue (interpret) vs combine_partials_ref,
    including all-invalid shards in the stack."""
    n, b, hq, d = 8, 3, 4, 32
    ks = jax.random.split(KEY, 3)
    m = jax.random.normal(ks[0], (n, b, hq)) * 3
    l = jnp.abs(jax.random.normal(ks[1], (n, b, hq))) + 0.1
    o = jax.random.normal(ks[2], (n, b, hq, d))
    # two shards contribute nothing (the co-placement identity element)
    m = m.at[1].set(ref.NEG_INF).at[4].set(ref.NEG_INF)
    l = l.at[1].set(0.0).at[4].set(0.0)
    o = o.at[1].set(0.0).at[4].set(0.0)
    got = combine_partials(m, l, o, interpret=True)
    exp = ref.combine_partials_ref(m, l, o, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=2e-6, rtol=2e-6)
    # all shards empty -> zeros, no NaN
    z = combine_partials(jnp.full_like(m, ref.NEG_INF), jnp.zeros_like(l),
                         jnp.zeros_like(o), interpret=True)
    assert np.all(np.asarray(z) == 0.0)


def _partials_fixture(n, seed):
    """n per-shard partials over disjoint token ranges of one softmax."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    b, h, t, d = 2, 3, 16, 8
    logits = jax.random.normal(ks[0], (n, b, h, t)) * 3
    v = jax.random.normal(ks[1], (n, b, h, t, d))
    m = logits.max(axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("nbht,nbhtd->nbhd", p, v)
    # shard 0 all-invalid when n allows: identity must drop out exactly
    if n >= 3:
        m = m.at[0].set(ref.NEG_INF)
        l = l.at[0].set(0.0)
        o = o.at[0].set(0.0)
    return m, l, o


@settings(max_examples=10)
@given(n=st.integers(2, 6), seed=st.integers(0, 1 << 16))
def test_combine_partials_associative_and_permutation_invariant(n, seed):
    """The flash-partial merge is an associative, commutative monoid with
    identity (NEG_INF, 0, 0): combining shard partials in any grouping or
    order yields the same softmax output — the algebra that makes the
    co-placed decode independent of bank count and shard order."""
    m, l, o = _partials_fixture(n, seed)
    flat = ref.combine_partials_ref(m, l, o, axis=0)

    # shard-permutation invariance (commutativity)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), n)
    permuted = ref.combine_partials_ref(m[perm], l[perm], o[perm], axis=0)
    np.testing.assert_allclose(np.asarray(permuted), np.asarray(flat),
                               atol=1e-5, rtol=1e-5)

    # associativity: pre-merge any prefix into ONE partial, then combine
    for k in range(1, n):
        mm, lm, om = ref.merge_partials_ref(m[:k], l[:k], o[:k], axis=0)
        m2 = jnp.concatenate([mm[None], m[k:]], axis=0)
        l2 = jnp.concatenate([lm[None], l[k:]], axis=0)
        o2 = jnp.concatenate([om[None], o[k:]], axis=0)
        grouped = ref.combine_partials_ref(m2, l2, o2, axis=0)
        np.testing.assert_allclose(np.asarray(grouped), np.asarray(flat),
                                   atol=1e-5, rtol=1e-5)


def test_ops_impl_validation():
    """kernels.ops raises on unknown impl strings (it used to fall through
    to the kernel path silently) and accepts the legacy "kernel" alias."""
    from repro.kernels import ops

    b, hq, hkv, t, d = 1, 2, 1, 16, 32
    ks = jax.random.split(KEY, 4)
    q = _rand(ks[0], (b, hq, d), jnp.float32)
    k = _rand(ks[1], (b, hkv, t, d), jnp.float32)
    v = _rand(ks[2], (b, hkv, t, d), jnp.float32)
    valid = jnp.ones((b, hkv, t), bool)
    tau_min = _rand(ks[3], (b, hkv, t, d), jnp.float32)   # (B,Hkv,C,D)
    tau_max = tau_min + 1.0
    for fn in (lambda i: ops.paged_attention(q, k, v, valid, impl=i),
               lambda i: ops.paged_attention_partial(q, k, v, valid, impl=i),
               lambda i: ops.flash_attention(
                   q[:, None], k.transpose(0, 2, 1, 3),
                   v.transpose(0, 2, 1, 3), impl=i),
               lambda i: ops.page_score(q, tau_min, tau_max, impl=i)):
        with pytest.raises(ValueError, match="valid impls"):
            fn("cuda")
    with pytest.raises(ValueError, match="valid impls"):
        ops.combine_partials(jnp.zeros((2, 1, 2)), jnp.zeros((2, 1, 2)),
                             jnp.zeros((2, 1, 2, 4)), impl="triton")
    # legacy alias still dispatches to the pallas path
    out = ops.paged_attention(q, k, v, valid, impl="kernel")
    exp = ops.paged_attention(q, k, v, valid, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_kernel_alias_deprecation_warns_once():
    """The legacy impl="kernel" alias (previously silently accepted)
    emits a DeprecationWarning exactly once per process and still
    resolves to "pallas"."""
    import warnings

    from repro.kernels import ops

    ops._warned_aliases.discard("kernel")   # reset the once-per-process latch
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert ops.resolve_impl("kernel") == "pallas"
        assert ops.resolve_impl("kernel") == "pallas"   # second call: silent
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "deprecated alias" in str(w.message)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    # canonical names never warn
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert ops.resolve_impl("pallas") == "pallas"
        assert ops.resolve_impl("ref") == "ref"
    assert not rec


def test_combine_partials_exact():
    """Cross-bank flash combine == softmax over the union (co-placement)."""
    ks = jax.random.split(KEY, 3)
    n, t, d = 4, 32, 16
    logits = jax.random.normal(ks[0], (n, t)) * 3
    v = jax.random.normal(ks[1], (n, t, d))
    m = logits.max(axis=1)
    p = jnp.exp(logits - m[:, None])
    l = p.sum(axis=1)
    o = jnp.einsum("nt,ntd->nd", p, v)
    got = ref.combine_partials_ref(m, l, o, axis=0)
    full = jax.nn.softmax(logits.reshape(-1))
    exp = jnp.einsum("t,td->d", full, v.reshape(-1, d))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-5)


@settings(max_examples=24)
@given(cq=st.sampled_from([1, 3, 8, 64]),
       density=st.sampled_from([0.0, 0.4, 1.0]),
       seed=st.integers(0, 1 << 16))
def test_chunk_attention_pallas_matches_ref(cq, density, seed):
    """Pallas-interpret chunk_attention vs chunk_attention_ref across
    chunk sizes × ragged validity masks (density=0.0 exercises the
    all-invalid rows -> 0 guard). Token-exactness of the chunked engine
    rides on this parity."""
    from repro.kernels.chunk_attention import chunk_attention

    b, hkv, g, t, d = 2, 2, 2, 37, 32
    hq = hkv * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = _rand(ks[0], (b, cq, hq, d), jnp.float32)
    k = _rand(ks[1], (b, hkv, t, d), jnp.float32)
    v = _rand(ks[2], (b, hkv, t, d), jnp.float32)
    valid = jax.random.bernoulli(ks[3], density, (b, hkv, cq, t))
    out = chunk_attention(q, k, v, valid, bt=16, interpret=True)
    exp = ref.chunk_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)
    if density == 0.0:
        assert np.all(np.asarray(out) == 0.0)


def _paged_chunk_fixture(cq, seed, dtype=jnp.float32):
    """A pre-append paged buffer + chunk: slot 0 resumes at start=13
    (one full + one partial page), slot 1 is a fresh slot (start=0,
    no pages written — the garbage buffer must be fully masked)."""
    b, hr, g, d = 2, 2, 2, 32
    cpages, page = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    start = jnp.asarray([13, 0], jnp.int32)
    ps = np.full((b, hr, cpages), -1, np.int32)
    ps[0, :, 0] = 0
    ps[0, :, 1] = 8
    return dict(
        q=_rand(ks[0], (b, cq, hr * g, d), dtype),
        k_pages=_rand(ks[1], (b, hr, cpages, page, d), dtype),
        v_pages=_rand(ks[2], (b, hr, cpages, page, d), dtype),
        page_start=jnp.asarray(ps),
        start=start,
        k_new=_rand(ks[3], (b, cq, hr, d), dtype),
        v_new=_rand(ks[4], (b, cq, hr, d), dtype))


@settings(max_examples=16)
@given(cq=st.sampled_from([1, 3, 8, 64]), seed=st.integers(0, 1 << 16))
def test_chunk_attention_paged_matches_post_append_oracle(cq, seed):
    """The fused pre-append body (ref AND pallas-interpret) equals the
    old formulation — chunk_attention_ref over the post-append buffer
    with an explicit positional mask — and the two impls agree: cache
    keys carry per-KEY validity (pos < start), the intra-chunk part a
    static causal triangle, and their union is the causal key set."""
    from repro.kernels.chunk_attention import chunk_attention_paged

    fx = _paged_chunk_fixture(cq, seed)
    got = ref.chunk_attention_paged_ref(**fx)
    pal = chunk_attention_paged(**fx, bt=8, interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(got),
                               atol=2e-5, rtol=2e-5)

    b, hr, cpages, page, d = fx["k_pages"].shape
    kb = fx["k_pages"].reshape(b, hr, cpages * page, d)
    vb = fx["v_pages"].reshape(b, hr, cpages * page, d)
    pos = (fx["page_start"][..., None] + jnp.arange(page)
           ).reshape(b, hr, cpages * page)
    ok = jnp.broadcast_to((fx["page_start"] >= 0)[..., None],
                          (b, hr, cpages, page)).reshape(b, hr, -1)
    cache_ok = ok & (pos < fx["start"][:, None, None])
    kc = jnp.concatenate([kb, fx["k_new"].transpose(0, 2, 1, 3)], axis=2)
    vc = jnp.concatenate([vb, fx["v_new"].transpose(0, 2, 1, 3)], axis=2)
    causal = jnp.arange(cq)[:, None] >= jnp.arange(cq)[None, :]
    mask = jnp.concatenate([
        jnp.broadcast_to(cache_ok[:, :, None, :],
                         (b, hr, cq, cpages * page)),
        jnp.broadcast_to(causal[None, None], (b, hr, cq, cq))], axis=-1)
    oracle = ref.chunk_attention_ref(fx["q"], kc, vc, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               atol=2e-5, rtol=2e-5)


def test_chunk_attention_impl_routing():
    """ops.chunk_attention used to silently ignore ``impl`` (always the
    ref body): unknown impls must now raise like every other op, and
    impl="pallas" must dispatch the real kernel (parity with ref).
    Same contract for the fused ops.chunk_attention_paged."""
    from repro.kernels import ops

    b, cq, hkv, g, t, d = 1, 3, 2, 2, 24, 32
    ks = jax.random.split(KEY, 4)
    q = _rand(ks[0], (b, cq, hkv * g, d), jnp.float32)
    k = _rand(ks[1], (b, hkv, t, d), jnp.float32)
    v = _rand(ks[2], (b, hkv, t, d), jnp.float32)
    valid = jax.random.bernoulli(ks[3], 0.6, (b, hkv, cq, t))
    with pytest.raises(ValueError, match="valid impls"):
        ops.chunk_attention(q, k, v, valid, impl="cuda")
    out = ops.chunk_attention(q, k, v, valid, impl="pallas")
    exp = ops.chunk_attention(q, k, v, valid, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)

    fx = _paged_chunk_fixture(cq=3, seed=7)
    with pytest.raises(ValueError, match="valid impls"):
        ops.chunk_attention_paged(**fx, impl="cuda")
    outp = ops.chunk_attention_paged(**fx, impl="pallas")
    expp = ops.chunk_attention_paged(**fx, impl="ref")
    np.testing.assert_allclose(np.asarray(outp), np.asarray(expp),
                               atol=2e-5, rtol=2e-5)


def test_chunk_attention_paged_casts_chunk_kv_to_cache_dtype():
    """A bf16 cache with f32 chunk KV must attend the ROUNDTRIPPED chunk
    keys (what a post-append body would read back), keeping chunked
    prefill invariant to when the append happens."""
    from repro.kernels import ops

    fx = _paged_chunk_fixture(cq=4, seed=11, dtype=jnp.bfloat16)
    fx32 = dict(fx, k_new=fx["k_new"].astype(jnp.float32),
                v_new=fx["v_new"].astype(jnp.float32))
    for impl in ("ref", "pallas"):
        a = ops.chunk_attention_paged(**fx, impl=impl)
        bb = ops.chunk_attention_paged(**fx32, impl=impl)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(bb, np.float32))


def test_chunked_ref_matches_dense():
    import repro.kernels.ref as R
    old_t, old_q = R.CHUNK_THRESHOLD, R.Q_CHUNK
    R.CHUNK_THRESHOLD, R.Q_CHUNK = 64, 64
    try:
        for win, sink in [(0, 0), (64, 4), (32, 0)]:
            ks = jax.random.split(jax.random.fold_in(KEY, win), 3)
            q = _rand(ks[0], (2, 256, 4, 32), jnp.float32)
            k = _rand(ks[1], (2, 256, 2, 32), jnp.float32)
            v = _rand(ks[2], (2, 256, 2, 32), jnp.float32)
            a = R._flash_attention_ref_chunked(
                q, k, v, causal=True, window=win, sink=sink, q_offset=0)
            b = R._flash_attention_ref_dense(
                q, k, v, causal=True, window=win, sink=sink, q_offset=0)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5)
    finally:
        R.CHUNK_THRESHOLD, R.Q_CHUNK = old_t, old_q
