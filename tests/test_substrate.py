"""Data pipeline, optimizer, checkpointing, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import ckpt
from repro.data import lm_batch, niah_batch
from repro.optim import (
    AdamWConfig,
    apply_updates,
    cosine_schedule,
    grad_compress,
    init_state,
)


def test_data_deterministic_and_seekable():
    """batch(step) is a pure function — restart-exactness for free."""
    a = lm_batch(jnp.int32(7), batch=4, seq=32, vocab=100)
    b = lm_batch(jnp.int32(7), batch=4, seq=32, vocab=100)
    c = lm_batch(jnp.int32(8), batch=4, seq=32, vocab=100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 100
    # labels are next-token shifted with -100 terminator
    np.testing.assert_array_equal(np.asarray(a["labels"][:, :-1]),
                                  np.asarray(a["tokens"][:, 1:]))
    assert np.all(np.asarray(a["labels"][:, -1]) == -100)


def test_niah_batch_structure():
    b = niah_batch(jnp.int32(0), batch=4, seq=64, vocab=256,
                   depth_frac=0.5)
    toks = np.asarray(b["tokens"])
    pos = b["needle_pos"]
    # needle key/value planted; query repeats the key at the end
    np.testing.assert_array_equal(toks[:, pos], toks[:, -1])
    assert np.all(np.asarray(b["answer"]) == toks[:, pos + 1])


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for step in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_cosine_schedule_shape():
    warm = cosine_schedule(jnp.int32(0), warmup=10, total=100)
    mid = cosine_schedule(jnp.int32(10), warmup=10, total=100)
    end = cosine_schedule(jnp.int32(100), warmup=10, total=100)
    assert float(warm) == 0.0
    assert float(mid) == pytest.approx(1.0, abs=1e-3)
    assert float(end) == pytest.approx(0.1, abs=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
              "d": jnp.int32(7)},
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, tree, step=3, metadata={"step": 3, "note": "x"})
    restored, meta = ckpt.restore(d, tree)
    assert meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["b"]["c"], np.float32),
        np.asarray(tree["b"]["c"], np.float32))
    assert int(restored["b"]["d"]) == 7


def test_checkpoint_atomicity_and_prune(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        ckpt.save(d, tree, step=s, metadata={"step": s})
    assert ckpt.latest_step(d) == 4
    ckpt.prune_old(d, keep=2)
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                   if x.startswith("step_"))
    assert steps == [3, 4]
    # a stale tmp dir never shadows a committed checkpoint
    os.makedirs(os.path.join(d, "tmp.99"), exist_ok=True)
    assert ckpt.latest_step(d) == 4


@settings(deadline=None, max_examples=25)
@given(scale=st.floats(1e-3, 1e3))
def test_int8_quantization_bounded_error(scale):
    g = jnp.array(np.random.default_rng(0).normal(size=(64,)) * scale,
                  jnp.float32)
    q, s = grad_compress.quantize_int8(g)
    deq = grad_compress.dequantize_int8(q, s)
    max_err = float(jnp.max(jnp.abs(deq - g)))
    assert max_err <= float(s) / 2 + 1e-6  # half-ULP of the int8 grid


def test_error_feedback_drives_bias_to_zero():
    """With a CONSTANT gradient, error feedback makes the long-run mean of
    the compressed stream converge to the true gradient."""
    g = {"w": jnp.array([0.3e-2, -1.7e-2, 0.9e-2])}
    err = grad_compress.init_error_feedback(g)
    total = jnp.zeros(3)
    n = 50
    for _ in range(n):
        qtree, err = grad_compress.compress_with_feedback(g, err)
        q, s = qtree["w"]
        total = total + grad_compress.dequantize_int8(q, s)
    mean = total / n
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g["w"]),
                               rtol=0.02)
