"""Rebalancing subsystem: cost model, planner, live-migration exactness.

The load-bearing property (paper §IV-B applied to continuous batching):
arming the rebalancer changes WHERE slots live, never WHAT they emit —
token traces are bit-identical to ``rebalance="off"`` in every serving
mode (packed / chunked prefill / speculative), with zero post-warmup
recompiles: the migrate jit is one more fixed-shape donated entry,
compiled once on the first applied plan. Migration copies cache rows
verbatim and sampling keys are owned by (seed, uid) — never the slot
index — so the trace cannot observe a move (docs/serving.md
§Rebalancing).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import H2ealConfig
from repro.models import model as M
from repro.sched import (
    CostModel,
    SlotCost,
    SlotView,
    device_compute_loads,
    plan_rebalance,
    slot_bank,
)
from repro.serving import Engine, Request

CAP = 64
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_arch("smollm-360m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _churn(cfg, *, n=12, seed=0):
    """Churn workload: ragged prompts AND ragged budgets, so retirements
    leave the batch skewed — the drift the rebalancer exists to undo."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        s = int(rng.choice([8, 16, 24]))
        g = int(rng.integers(3, 20))
        prompt = rng.integers(0, cfg.vocab_size, size=(s,)).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=g))
    return reqs


# ---------------------------------------------------------------------------
# cost model (sched/cost.py)
# ---------------------------------------------------------------------------

H2 = H2ealConfig(sink=4, local=8, select_budget=16, page_size=8)


def test_cost_model_head_mix_from_config(model):
    cfg, _ = model
    cm = CostModel.from_config(cfg)
    n_kv = cfg.num_kv_heads
    nr = max(n_kv - round(n_kv * cfg.h2eal.static_sparsity), 0)
    assert (cm.n_retrieval, cm.n_streaming) == (nr, n_kv - nr)


def test_decode_cost_streaming_saturates_retrieval_grows():
    """The drift source: streaming saturates at sink+local, retrieval
    keeps growing with live pages (metadata scan) past the budget."""
    stream_only = CostModel(h2=H2, n_retrieval=0, n_streaming=1)
    sat = H2.sink + H2.local
    assert stream_only.decode_cost(sat)[0] \
        == stream_only.decode_cost(10 * sat)[0]
    retr_only = CostModel(h2=H2, n_retrieval=1, n_streaming=0)
    big = H2.sink + H2.local + H2.select_budget
    assert retr_only.decode_cost(4 * big)[0] \
        > retr_only.decode_cost(2 * big)[0]


def test_decode_cost_spec_horizon():
    """spec_tokens=k scores at ctx + k - 1: a verify step appends up to
    k tokens before the host can rebalance."""
    base = CostModel(h2=H2, n_retrieval=1, n_streaming=1)
    spec = CostModel(h2=H2, n_retrieval=1, n_streaming=1, spec_tokens=4)
    assert spec.decode_cost(10) == base.decode_cost(13)


def test_decode_cost_hot_cap_limits_pages():
    capped = CostModel(h2=H2, n_retrieval=1, n_streaming=0, hot_cap=3)
    assert capped.decode_cost(30 * H2.page_size)[2] == 3
    uncapped = CostModel(h2=H2, n_retrieval=1, n_streaming=0)
    assert uncapped.decode_cost(30 * H2.page_size)[2] == 30


def test_prefill_grants_allocated_jointly():
    """Two prefilling slots share ONE chunk budget per step — per-slot
    optimism would double-count the backlog."""
    cm = CostModel(h2=H2, n_retrieval=1, n_streaming=1, chunk_budget=8)
    views = [SlotView(slot=0, uid=0, ctx=0, prompt_left=32,
                      phase="prefill"),
             SlotView(slot=1, uid=1, ctx=0, prompt_left=32,
                      phase="prefill")]
    costs = cm.slot_costs(views)
    heads = cm.n_retrieval + cm.n_streaming
    granted = sum((c.compute - c.paged_compute) / heads for c in costs)
    assert 0 < granted <= 8  # joint grant never exceeds the shared budget


def test_device_loads_conserve_and_pin():
    costs = [SlotCost(slot=0, uid=0, phase="decode", compute=10.0,
                      paged_compute=4.0, pages=2),
             SlotCost(slot=3, uid=1, phase="decode", compute=6.0,
                      paged_compute=2.0, pages=1)]
    loads = device_compute_loads(costs, n_banks=2, max_batch=4)
    assert sum(loads) == pytest.approx(16.0)  # nothing lost or invented
    assert loads == [10.0, 6.0]  # unstriped: whole slot pins to its bank


def test_device_loads_striped_share_follows_pages():
    """Striping moves ONLY the paged share: the pinned share stays on
    slot_bank, the paged share spreads over the stripe devices."""
    costs = [SlotCost(slot=0, uid=0, phase="decode", compute=10.0,
                      paged_compute=4.0, pages=2)]
    loads = device_compute_loads(costs, n_banks=2, max_batch=4,
                                 page_stripe_shards=2)
    assert loads == pytest.approx([6.0 + 2.0, 2.0])
    assert slot_bank(0, n_banks=2, max_batch=4) == 0


# ---------------------------------------------------------------------------
# planner (sched/rebalance.py)
# ---------------------------------------------------------------------------

def _cost(slot, compute, uid=None):
    return SlotCost(slot=slot, uid=slot if uid is None else uid,
                    phase="decode", compute=float(compute),
                    paged_compute=0.0, pages=0)


def test_plan_no_moves_when_balanced():
    costs = [_cost(0, 5.0), _cost(2, 5.0)]
    plan = plan_rebalance(costs, [1, 3], n_banks=2, max_batch=4)
    assert plan.moves == ()
    assert plan.imbalance_before == plan.imbalance_after == 1.0


def test_plan_moves_reduce_imbalance():
    """Both live slots crowded into bank 0 with bank 1 empty: the plan
    moves one into the free bank and the simulated imbalance drops."""
    costs = [_cost(0, 5.0), _cost(1, 5.0)]
    plan = plan_rebalance(costs, [2, 3], n_banks=2, max_batch=4)
    assert len(plan.moves) == 1
    mv = plan.moves[0]
    assert mv.src in (0, 1) and mv.dst in (2, 3)
    assert plan.imbalance_before == 2.0
    assert plan.imbalance_after == 1.0
    assert plan.gain == pytest.approx(1.0)


def test_plan_hysteresis_blocks_small_gains():
    costs = [_cost(0, 5.0), _cost(1, 5.0)]
    plan = plan_rebalance(costs, [2, 3], n_banks=2, max_batch=4,
                          min_gain=2.0)  # achievable gain is only 1.0
    assert plan.moves == ()
    assert plan.imbalance_before == plan.imbalance_after  # nothing applied


def test_plan_degenerate_inputs_empty():
    costs = [_cost(0, 9.0), _cost(1, 1.0)]
    assert plan_rebalance(costs, [], n_banks=2, max_batch=4).moves == ()
    assert plan_rebalance(costs, [2, 3], n_banks=1, max_batch=4).moves == ()
    assert plan_rebalance(costs[:1], [2, 3], n_banks=2,
                          max_batch=4).moves == ()


def test_plan_moves_only_into_free_slots_and_deterministic():
    costs = [_cost(0, 9.0), _cost(1, 5.0), _cost(4, 1.0)]
    free = [2, 3, 5, 6, 7]
    occupied = {c.slot for c in costs}
    a = plan_rebalance(costs, free, n_banks=4, max_batch=8)
    b = plan_rebalance(list(costs), list(reversed(free)), n_banks=4,
                       max_batch=8)
    assert a == b  # free-list order and input aliasing don't matter
    taken = set()
    for mv in a.moves:
        assert mv.dst in set(free) | {c.slot for c in costs}
        assert mv.dst not in occupied - {m.src for m in a.moves}
        assert mv.dst not in taken  # no two moves share a destination
        taken.add(mv.dst)
    assert a.imbalance_after <= a.imbalance_before


# ---------------------------------------------------------------------------
# engine integration: migration exactness (the tentpole property)
# ---------------------------------------------------------------------------

def _serve(cfg, params, reqs, **kw):
    eng = Engine(cfg, params, max_batch=4, capacity=CAP,
                 prompt_buckets=[8, 16, 24], **kw)
    return eng, eng.run(reqs)


@pytest.mark.parametrize("mode", ["packed", "chunked", "spec"])
def test_rebalance_retire_token_exact(model, mode):
    """retire-triggered migration vs rebalance="off" on the churn
    workload: identical tokens per uid, migrations actually happened,
    and a second run reuses every compiled entry (the migrate jit
    compiles once, on the first applied plan)."""
    cfg, params = model
    kw = {"packed": {},
          "chunked": {"prefill_chunk": 8},
          "spec": {"spec_tokens": 4}}[mode]
    reqs = _churn(cfg)
    _, c_off = _serve(cfg, params, reqs, rebalance="off", **kw)
    eng, c_rb = _serve(cfg, params, reqs, rebalance="retire", **kw)
    assert sorted(c_off) == sorted(c_rb)
    for uid in sorted(c_off):
        assert c_off[uid].tokens == c_rb[uid].tokens, uid
    s = eng.stats
    assert s.migrations > 0, s  # the property is vacuous without moves
    assert s.rebalances > 0
    # imbalance accounting: applying a plan can only flatten the banks
    assert s.imbalance_post <= s.imbalance_pre
    assert s.imbalance_post < s.imbalance_pre  # >=1 plan applied => strict
    # zero post-warmup recompiles across a differently-shaped rerun
    sizes0 = eng.jit_cache_sizes()
    assert sizes0.get("migrate", 0) == 1, sizes0
    eng.reset_metrics()
    eng.run(_churn(cfg, seed=5))
    assert eng.jit_cache_sizes() == sizes0, (sizes0, eng.jit_cache_sizes())


def test_rebalance_interval_trigger(model):
    """interval trigger: same exactness, checks happen on the step
    boundary even without retirements in between."""
    cfg, params = model
    reqs = _churn(cfg)
    _, c_off = _serve(cfg, params, reqs, rebalance="off")
    eng, c_rb = _serve(cfg, params, reqs, rebalance="interval",
                       rebalance_interval=4, rebalance_cooldown=2)
    for uid in sorted(c_off):
        assert c_off[uid].tokens == c_rb[uid].tokens, uid
    assert eng.stats.rebalance_checks > 0
    assert eng.stats.migrations > 0


def test_rebalance_invalid_trigger_rejected(model):
    cfg, params = model
    with pytest.raises(ValueError, match="valid triggers"):
        Engine(cfg, params, max_batch=2, capacity=CAP,
               prompt_buckets=[8], rebalance="bogus")


def test_compute_loads_report_any_engine(model):
    """Engine.compute_loads works with rebalance off (the balance report
    path) and returns one load per bank."""
    cfg, params = model
    eng = Engine(cfg, params, max_batch=4, capacity=CAP,
                 prompt_buckets=[8])
    loads = eng.compute_loads()
    assert len(loads) == eng.rebalance_banks
    assert all(x == 0.0 for x in loads)  # nothing admitted yet


REBALANCE_COPLACE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.models import model as M
from tests.test_rebalance import CAP, _churn
from repro.serving import Engine

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
reqs = _churn(cfg)
kw = dict(max_batch=4, capacity=CAP, prompt_buckets=[8, 16, 24],
          layout="coplace_shmap", admission="balanced")
e0 = Engine(cfg, params, **kw)
c0 = e0.run(reqs)
# rebalance_banks=2: with 8 shards the default would clamp to
# max_batch=4 banks -- one slot per bank, pure permutations, no gain
e1 = Engine(cfg, params, rebalance="retire", rebalance_banks=2, **kw)
c1 = e1.run(reqs)
assert sorted(c0) == sorted(c1)
for uid in sorted(c0):
    assert c0[uid].tokens == c1[uid].tokens, (
        uid, c0[uid].tokens, c1[uid].tokens)
assert e1.stats.migrations > 0, e1.stats
assert e1.stats.imbalance_post <= e1.stats.imbalance_pre
sizes0 = e1.jit_cache_sizes()
# entry counts per function vary under shard_map (input shardings differ
# by call site, like decode_select); the invariant is stability below
assert sizes0.get("migrate", 0) >= 1, sizes0
e1.reset_metrics()
e1.run(_churn(cfg, seed=5))
assert e1.jit_cache_sizes() == sizes0, (sizes0, e1.jit_cache_sizes())
print("REBALANCE_COPLACE_EXACT")
"""


@pytest.mark.slow
def test_rebalance_coplace_shmap_exact_8dev():
    """8-fake-device subprocess (the ISSUE-9 acceptance check): the
    retire-triggered rebalancer under shard_map co-placement migrates
    slots across the sharded serve state — donated dynamic-index copy
    with pinned out_shardings — and stays token-exact vs rebalance="off"
    with zero post-warmup recompiles."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", REBALANCE_COPLACE_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=520, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "REBALANCE_COPLACE_EXACT" in out.stdout
