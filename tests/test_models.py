"""Per-architecture smoke tests (reduced configs) + mixer exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch, reduced
from repro.configs.base import H2ealConfig, SSMConfig, ArchConfig
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_smoke(name):
    """One forward + prefill + decode step on CPU: shapes + no NaNs."""
    cfg = reduced(get_arch(name))
    p = M.init_params(cfg, KEY)
    b, s = 2, 48
    if cfg.embed_frontend_stub:
        batch = jax.random.normal(KEY, (b, s, cfg.d_model))
        tok = jax.random.normal(KEY, (b, cfg.d_model))
    else:
        batch = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        tok = jax.random.randint(KEY, (b,), 0, cfg.vocab_size)
    logits = M.forward(cfg, p, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    lg, st = M.prefill(cfg, p, batch, capacity=s + 16)
    assert lg.shape == (b, cfg.vocab_size)
    lg2, st = M.decode_step(cfg, p, st, tok)
    assert lg2.shape == (b, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg2, np.float32)))


@pytest.mark.parametrize("name", ["smollm-360m", "gemma3-1b", "zamba2-2.7b",
                                  "xlstm-125m", "qwen3-moe-235b-a22b"])
def test_decode_matches_forward_full_attention(name):
    """Teacher-forced: prefill+decode logits == forward logits (baseline
    full-attention path; exactness of the whole serving stack)."""
    cfg = reduced(get_arch(name))
    cfg = dataclasses.replace(cfg, h2eal=H2ealConfig(enabled=False))
    p = M.init_params(cfg, KEY)
    b, s, extra = 1, 40, 4
    if cfg.embed_frontend_stub:
        toks = jax.random.normal(KEY, (b, s + extra, cfg.d_model))
    else:
        toks = jax.random.randint(KEY, (b, s + extra), 0, cfg.vocab_size)
    full = M.forward(cfg, p, toks)
    lg, st = M.prefill(cfg, p, toks[:, :s], capacity=s + extra + 8)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, s - 1]),
                               atol=2e-3)
    for t in range(extra):
        lg, st = M.decode_step(cfg, p, st, toks[:, s + t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, s + t]), atol=2e-3)


def test_hybrid_decode_matches_forward_when_topk_covers_all():
    """H²EAL with top-k spanning all pages ≡ full attention end-to-end."""
    cfg = reduced(get_arch("smollm-360m"))
    big = H2ealConfig(sink=2, local=16, page_size=8, select_budget=4096,
                      share_window=1)
    cfg = dataclasses.replace(cfg, h2eal=big)
    p = M.init_params(cfg, KEY)
    b, s, extra = 1, 40, 3
    toks = jax.random.randint(KEY, (b, s + extra), 0, cfg.vocab_size)
    # oracle: mixed attention — retrieval heads full, streaming sink+local.
    # For exactness vs M.forward we need ALL heads retrieval:
    cfg0 = dataclasses.replace(cfg, h2eal=dataclasses.replace(
        big, static_sparsity=0.0))
    full = M.forward(cfg0, p, toks)
    lg, st = M.prefill(cfg0, p, toks[:, :s], capacity=s + extra + 8)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, s - 1]),
                               atol=2e-3)
    for t in range(extra):
        lg, st = M.decode_step(cfg0, p, st, toks[:, s + t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, s + t]), atol=2e-3)


def _tiny_ssm_cfg():
    return ArchConfig(
        name="t", family="hybrid", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=128,
        ssm=SSMConfig(state_dim=8, conv_dim=4, expand=2, head_dim=16,
                      chunk=8))


def test_mamba2_chunked_equals_recurrent():
    from repro.models.ssm import (init_mamba2, init_mamba2_state,
                                  mamba2_forward, mamba2_step)
    cfg = _tiny_ssm_cfg()
    p = init_mamba2(KEY, cfg)
    b, L = 2, 37
    x = jax.random.normal(jax.random.PRNGKey(1), (b, L, 32))
    y_par = mamba2_forward(cfg, p, x)
    st = init_mamba2_state(cfg, b)
    ys = []
    for t in range(L):
        yt, st = mamba2_step(cfg, p, st, x[:, t])
        ys.append(yt)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-3)


def test_mamba2_prefill_state_matches_step_state():
    from repro.models.ssm import (init_mamba2, init_mamba2_state,
                                  mamba2_final_state, mamba2_step)
    cfg = _tiny_ssm_cfg()
    p = init_mamba2(KEY, cfg)
    b, L = 1, 24
    x = jax.random.normal(jax.random.PRNGKey(2), (b, L, 32))
    st = init_mamba2_state(cfg, b)
    for t in range(L):
        _, st = mamba2_step(cfg, p, st, x[:, t])
    st2 = mamba2_final_state(cfg, p, x)
    np.testing.assert_allclose(np.asarray(st["ssm"]), np.asarray(st2["ssm"]),
                               atol=1e-3)
    for k in ("conv_x", "conv_B", "conv_C"):
        np.testing.assert_allclose(np.asarray(st[k]), np.asarray(st2[k]),
                                   atol=1e-5)


@pytest.mark.parametrize("kind", ["mlstm", "slstm"])
def test_xlstm_forward_equals_stepwise(kind):
    from repro.models import xlstm as X
    cfg = ArchConfig(name="t", family="ssm", num_layers=1, d_model=32,
                     num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=128)
    b, L = 2, 19
    x = jax.random.normal(jax.random.PRNGKey(3), (b, L, 32))
    if kind == "mlstm":
        p = X.init_mlstm(KEY, cfg)
        y_par = X.mlstm_forward(cfg, p, x)
        st = X.init_mlstm_state(cfg, b)
        step = X.mlstm_step
    else:
        p = X.init_slstm(KEY, cfg)
        y_par = X.slstm_forward(cfg, p, x)
        st = X.init_slstm_state(cfg, b)
        step = X.slstm_step
    ys = []
    for t in range(L):
        yt, st = step(cfg, p, st, x[:, t])
        ys.append(yt)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4)


def test_moe_routing_mass_conservation():
    """Router weights are renormalized over top-k: with capacity ample, the
    MoE output is a convex combination of expert outputs (finite, bounded,
    and zero tokens routed nowhere)."""
    from repro.models.moe import init_moe, moe_ffn
    cfg = reduced(get_arch("qwen3-moe-235b-a22b"))
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y = moe_ffn(cfg, p, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    # permutation invariance over batch: tokens are routed independently
    y2 = moe_ffn(cfg, p, x[::-1])
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y[::-1]),
                               atol=2e-5)


def test_gemma3_local_global_pattern():
    cfg = get_arch("gemma3-1b")
    globals_ = [i for i in range(cfg.num_layers)
                if cfg.layer_is_global_attn(i)]
    assert globals_ == [5, 11, 17, 23]  # 5:1 ratio, 26 layers
    cfgr = reduced(cfg)
    assert cfgr.local_window > 0


def test_gating_identifies_streaming_heads():
    """α-gated attention: heads whose α→0 behave as streaming heads."""
    from repro.core.gating import classify_heads, gated_attention
    b, s, hq, hkv, d = 1, 64, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    from repro.kernels.ref import flash_attention_ref
    full = flash_attention_ref(q, k, v, causal=True)
    stream = flash_attention_ref(q, k, v, causal=True, window=8, sink=2)
    alpha = jnp.array([1.0, 0.0])
    out = gated_attention(q, k, v, alpha, sink=2, local=8)
    g = hq // hkv
    np.testing.assert_allclose(np.asarray(out[:, :, :g]),
                               np.asarray(full[:, :, :g]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[:, :, g:]),
                               np.asarray(stream[:, :, g:]), atol=1e-5)
    perm = classify_heads(jnp.array([[0.1, 0.9], [0.8, 0.2]]), 0.5)
    assert perm.shape == (2, 2)
    assert int(perm[0, 0]) == 1 and int(perm[1, 0]) == 0
