"""H²EAL hybrid attention: decode/prefill against brute-force oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import H2ealConfig
from repro.core.hybrid_attention import (
    AttnSpec,
    decode_attention,
    init_decode_state,
    prefill_attention,
)
from repro.kernels.ref import flash_attention_ref, paged_attention_ref

KEY = jax.random.PRNGKey(0)
B, HQ, HKV, D = 2, 4, 2, 32
P, SINK, LOCAL = 8, 2, 16


def _spec(select_budget=96, share_window=1, static_sparsity=0.5):
    h2 = H2ealConfig(sink=SINK, local=LOCAL, page_size=P,
                     select_budget=select_budget,
                     share_window=share_window,
                     static_sparsity=static_sparsity)
    return AttnSpec(n_q=HQ, n_kv=HKV, head_dim=D, h2=h2)


def _oracle(qn, k_all, v_all, ctx, nr):
    """retrieval heads -> full attention; streaming -> sink+local."""
    kt = k_all.transpose(0, 2, 1, 3)
    vt = v_all.transpose(0, 2, 1, 3)
    pos = jnp.arange(ctx)
    g = HQ // HKV
    valid_full = jnp.broadcast_to(pos[None, None] < ctx, (B, HKV, ctx))
    valid_sl = jnp.broadcast_to(
        (pos[None, None] < SINK) | (pos[None, None] >= ctx - LOCAL),
        (B, HKV, ctx))
    o_full = paged_attention_ref(qn, kt, vt, valid_full)
    o_sl = paged_attention_ref(qn, kt, vt, valid_sl)
    return jnp.concatenate(
        [o_full.reshape(B, HKV, g, D)[:, :nr],
         o_sl.reshape(B, HKV, g, D)[:, nr:]], axis=1).reshape(B, HQ, D)


@pytest.mark.parametrize("s", [96, 97, 104, 20, 33])
def test_decode_topk_all_equals_full(s):
    """top-k spanning all pages ⇒ retrieval heads == full attention."""
    spec = _spec()
    ks = jax.random.split(jax.random.fold_in(KEY, s), 5)
    k = jax.random.normal(ks[0], (B, s, HKV, D))
    v = jax.random.normal(ks[1], (B, s, HKV, D))
    paged, stream = init_decode_state(spec, k, v, s, capacity=s + 32)
    qn = jax.random.normal(ks[2], (B, HQ, D))
    kn = jax.random.normal(ks[3], (B, HKV, D))
    vn = jax.random.normal(ks[4], (B, HKV, D))
    out, _, _ = decode_attention(spec, qn, kn, vn, paged, stream,
                                 jnp.int32(s), do_select=True)
    k_all = jnp.concatenate([k, kn[:, None]], axis=1)
    v_all = jnp.concatenate([v, vn[:, None]], axis=1)
    exp = _oracle(qn, k_all, v_all, s + 1, spec.n_retrieval)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


def test_decode_multistep_matches_oracle():
    """Multi-step decode with top-k=all stays exact at every step."""
    spec = _spec()
    s = 64
    ks = jax.random.split(KEY, 2)
    k = jax.random.normal(ks[0], (B, s, HKV, D))
    v = jax.random.normal(ks[1], (B, s, HKV, D))
    paged, stream = init_decode_state(spec, k, v, s, capacity=128)
    k_all, v_all = k, v
    length = jnp.int32(s)
    for step in range(6):
        kk = jax.random.split(jax.random.fold_in(KEY, 100 + step), 3)
        qn = jax.random.normal(kk[0], (B, HQ, D))
        kn = jax.random.normal(kk[1], (B, HKV, D))
        vn = jax.random.normal(kk[2], (B, HKV, D))
        out, paged, stream = decode_attention(
            spec, qn, kn, vn, paged, stream, length, do_select=True)
        k_all = jnp.concatenate([k_all, kn[:, None]], axis=1)
        v_all = jnp.concatenate([v_all, vn[:, None]], axis=1)
        exp = _oracle(qn, k_all, v_all, int(length) + 1, spec.n_retrieval)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-4)
        length = length + 1


def test_sparse_decode_share_window_runs_finite():
    spec = _spec(select_budget=16, share_window=2)
    ks = jax.random.split(KEY, 2)
    k = jax.random.normal(ks[0], (B, 64, HKV, D))
    v = jax.random.normal(ks[1], (B, 64, HKV, D))
    paged, stream = init_decode_state(spec, k, v, 64, capacity=128)
    length = jnp.int32(64)
    for step in range(8):
        kk = jax.random.split(jax.random.fold_in(KEY, 200 + step), 3)
        qn = jax.random.normal(kk[0], (B, HQ, D))
        kn = jax.random.normal(kk[1], (B, HKV, D))
        vn = jax.random.normal(kk[2], (B, HKV, D))
        out, paged, stream = decode_attention(
            spec, qn, kn, vn, paged, stream, length,
            do_select=(step % 2 == 0))
        assert np.all(np.isfinite(np.asarray(out)))
        length = length + 1


def test_prefill_split_matches_per_head_reference():
    spec = _spec()
    s = 96
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, s, HQ, D))
    k = jax.random.normal(ks[1], (B, s, HKV, D))
    v = jax.random.normal(ks[2], (B, s, HKV, D))
    out = prefill_attention(spec, q, k, v)
    nr = spec.n_retrieval
    g = HQ // HKV
    qg = q.reshape(B, s, HKV, g, D)
    o_r = flash_attention_ref(qg[:, :, :nr].reshape(B, s, nr * g, D),
                              k[:, :, :nr], v[:, :, :nr], causal=True)
    o_s = flash_attention_ref(qg[:, :, nr:].reshape(B, s, (HKV - nr) * g, D),
                              k[:, :, nr:], v[:, :, nr:], causal=True,
                              window=LOCAL, sink=SINK)
    exp = jnp.concatenate([o_r.reshape(B, s, nr, g, D),
                           o_s.reshape(B, s, HKV - nr, g, D)],
                          axis=2).reshape(B, s, HQ, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_head_permutation_roundtrip():
    """A non-identity perm must give the same per-head outputs, re-ordered
    consistently (outputs return in original head order)."""
    spec = _spec()
    s = 96
    ks = jax.random.split(KEY, 5)
    k = jax.random.normal(ks[0], (B, s, HKV, D))
    v = jax.random.normal(ks[1], (B, s, HKV, D))
    q = jax.random.normal(ks[2], (B, s, HQ, D))
    perm = jnp.array([1, 0], jnp.int32)
    out_id = prefill_attention(spec, q, k, v, jnp.array([0, 1], jnp.int32))
    out_pm = prefill_attention(spec, q, k, v, perm)
    # with perm [1,0], head 1 becomes retrieval and head 0 streaming — so
    # outputs differ; but permuting the INPUT heads the same way must agree
    g = HQ // HKV
    qp = q.reshape(B, s, HKV, g, D)[:, :, perm].reshape(B, s, HQ, D)
    out_manual = prefill_attention(spec, qp, k[:, :, perm], v[:, :, perm],
                                   jnp.array([0, 1], jnp.int32))
    got = out_pm.reshape(B, s, HKV, g, D)[:, :, perm].reshape(B, s, HQ, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(out_manual),
                               atol=1e-5)
    del out_id


def test_static_sparsity_zero_means_all_retrieval():
    spec = _spec(static_sparsity=0.0)
    assert spec.n_retrieval == HKV and spec.n_streaming == 0
    spec1 = _spec(static_sparsity=1.0)
    assert spec1.n_retrieval == 0 and spec1.n_streaming == HKV
