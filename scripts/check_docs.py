#!/usr/bin/env python3
"""Docs drift check: fail on dead relative markdown links and on
references to missing repo files in *.md files and module docstrings
(the way hbsim/sim.py cited an EXPERIMENTS.md that did not exist).

A reference resolves if the path exists relative to the referencing
file, the repo root, src/, or src/repro/ — or, for bare shorthand like
``engine.py``, if the basename exists anywhere in the repo. SNIPPETS.md
and PAPERS.md are skipped (they cite external repos by design).
"""
import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
FILEREF = re.compile(r"[A-Za-z0-9_./-]*[A-Za-z0-9_-]+\.(?:md|py|sh)\b")
SKIP_BARE = {"SNIPPETS.md", "PAPERS.md"}
BASENAMES = {p.name for p in ROOT.rglob("*") if ".git" not in p.parts}


def resolves(ref: str, base: Path) -> bool:
    if "://" in ref or ref.startswith("mailto:"):
        return True
    roots = (base, ROOT, ROOT / "src", ROOT / "src" / "repro")
    if any((r / ref).exists() for r in roots):
        return True
    return "/" not in ref and ref in BASENAMES


def main() -> int:
    bad = []
    for md in sorted(ROOT.rglob("*.md")):
        if ".git" in md.parts:
            continue
        text = md.read_text()
        rel = md.relative_to(ROOT)
        for m in LINK.finditer(text):
            if not resolves(m.group(1), md.parent):
                bad.append(f"{rel}: dead link -> {m.group(1)}")
        if md.name not in SKIP_BARE:
            for ref in set(FILEREF.findall(text)):
                if not resolves(ref, md.parent):
                    bad.append(f"{rel}: missing file reference -> {ref}")
    for py in sorted(ROOT.rglob("*.py")):
        if ".git" in py.parts:
            continue
        try:
            doc = ast.get_docstring(ast.parse(py.read_text())) or ""
        except SyntaxError:
            continue
        for ref in set(FILEREF.findall(doc)):
            if not resolves(ref, py.parent):
                bad.append(f"{py.relative_to(ROOT)}: docstring references "
                           f"missing file -> {ref}")
    for line in bad:
        print(f"docs-check: {line}")
    print(f"docs-check: {'FAIL' if bad else 'OK'} "
          f"({len(bad)} dead reference(s))")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
