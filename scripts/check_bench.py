#!/usr/bin/env python
"""Perf gate over the BENCH_serve.json artifact.

scripts/ci.sh produces BENCH_serve.json (benchmarks/serve_throughput.py)
on every full run; this script holds it against the committed bands in
benchmarks/bench_bands.json so perf and correctness drift fail CI
instead of silently rewriting the artifact:

  exact checks (deterministic on any host)
    - every banded row is present (coverage: a row disappearing from the
      benchmark is a failure, not a skip)
    - recompiled_after_warmup is False on every engine row
    - tokens_match_packed / tokens_match_ref are True wherever emitted
      (chunked admission vs prefill-then-pack; pallas vs ref)

  banded checks (wall-clock metrics; wide multiplicative bands because
  CI hosts are contended CPUs running interpret-mode kernels)
    - tokens_per_s within [ref * lo, ref * hi]
    - ttft_p50_s / ttft_p99_s within their band on poisson rows

Rows are keyed by the metrics that select a compiled serving
configuration: (mode, layout, impl, prefill_chunk, admission_mode,
tier) — tier is "-" for untiered rows, "resident"/"tiered" for the
hot/cold residency pair (tokens_match_resident joins the exact flags
there, and a ratio gate holds the tiered row's throughput against the
all-resident oracle). Fused decode-window rows (PR 10,
``Engine(decode_window=w)``) append a ``win{w}`` key component —
only when decode_window > 1, so existing keys are stable — and carry
three extra gates: ``tokens_match_unfused`` joins the exact flags, a
ratio gate holds fused tokens/s against the per-step row on the same
widened-share-window config, and a dispatch gate bounds the fused
row's dispatch count to ``per_window * ceil(decode_steps / w) +
const`` (the constant absorbs admission, select-boundary, and sampling
dispatches) so the row can't silently fall back to per-step dispatch.

Regenerate the reference values after an intentional perf change with

    PYTHONPATH=src python benchmarks/serve_throughput.py ... \
        --json BENCH_serve.json
    python scripts/check_bench.py --update

and commit both files; the bands themselves (lo/hi factors) are
hand-maintained in bench_bands.json.

``--append-trend PATH`` additionally appends one JSONL row (keyed by
the current git commit; re-running on the same commit replaces its row,
so the file stays one-row-per-PR) with every row's tokens_per_s and the
tiered residency counters — the cross-PR perf trajectory artifact.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH = os.path.join(REPO, "BENCH_serve.json")
BANDS = os.path.join(REPO, "benchmarks", "bench_bands.json")

BANDED = ("tokens_per_s", "ttft_p50_s", "ttft_p99_s")
EXACT_TRUE = ("tokens_match_packed", "tokens_match_ref",
              "tokens_match_resident", "tokens_match_nonspec",
              "tokens_match_norebalance", "tokens_match_unfused")

# fields every bench row MUST carry for keying — a rename in
# benchmarks/serve_throughput.py._row() otherwise surfaced as a raw
# KeyError deep inside this script
ROW_KEY_FIELDS = ("mode", "layout", "impl")
# minimum schema of one bench_trend.jsonl row (validated on
# --append-trend so a schema drift fails loudly at append time, not
# when a later reader chokes on the file)
TREND_SCHEMA = {"commit": str, "tokens_per_s": dict}


def _schema_fail(msg):
    raise SystemExit(f"check_bench: SCHEMA {msg}")


def _require(mapping, key, where, hint=""):
    """Named, actionable lookup: a missing/renamed key names the file
    and the expected field instead of raising a bare KeyError."""
    if key not in mapping:
        _schema_fail(f"{where} is missing required key {key!r}"
                     + (f" — {hint}" if hint else ""))
    return mapping[key]


def row_key(row):
    missing = [f for f in ROW_KEY_FIELDS if f not in row]
    if missing:
        _schema_fail(
            f"bench row is missing key field(s) {missing} "
            f"(row has: {sorted(row)[:12]}); "
            "benchmarks/serve_throughput.py._row() must emit "
            f"{list(ROW_KEY_FIELDS)} — a rename needs a matching update "
            "here AND in the benchmarks/bench_bands.json row keys")
    # sampled / speculative rows (PR 8) select their own compiled
    # configuration (sample + verify jits), so they key separately:
    # "greedy" vs "t<temp>,p<top_p>", spec-k, and the dedicated
    # ngram-friendly gate workload vs the default random one
    samp = row.get("sampling")
    samp_key = (f"t{samp['temperature']},p{samp['top_p']}" if samp
                else "greedy")
    key = "|".join([row["mode"], row["layout"], row["impl"],
                    f"chunk{row.get('prefill_chunk', 0)}",
                    row.get("admission_mode", "-"),
                    row.get("tier", "-"),
                    samp_key,
                    f"spec{row.get('spec_tokens', 0)}",
                    f"wl:{row.get('workload', 'default')}"])
    # fused decode-window rows (PR 10) select their own compiled
    # configuration (the fused scan jit); per-step rows (window 1 or
    # absent) keep the legacy key so existing bands stay stable
    dw = row.get("decode_window", 0) or 0
    if dw > 1:
        key += f"|win{dw}"
    return key


def check(bench_path=BENCH, bands_path=BANDS):
    with open(bench_path) as f:
        bench = json.load(f)
    with open(bands_path) as f:
        bands = json.load(f)
    band = _require(bands, "band", bands_path,
                    "the multiplicative band-factor table "
                    "{metric: [lo, hi]} with a 'default' entry")
    _require(band, "default", f"{bands_path} 'band'",
             "the fallback [lo, hi] pair for metrics without their own")
    band_rows = _require(bands, "rows", bands_path,
                         "the {row_key: {metric: ref}} reference table; "
                         "regenerate with --update")
    rows = {row_key(r): r for r in _require(bench, "rows", bench_path,
                                            "the benchmark row list")}
    errors = []

    for key, ref in band_rows.items():
        row = rows.get(key)
        if row is None:
            errors.append(f"{key}: banded row missing from {bench_path}")
            continue
        if row.get("recompiled_after_warmup", False):
            errors.append(f"{key}: recompiled after warmup")
        for flag in EXACT_TRUE:
            if flag in row and row[flag] is not True:
                errors.append(f"{key}: {flag} is {row[flag]}")
        for metric, value in ref.items():
            if metric not in BANDED or metric not in row:
                continue
            lo, hi = band.get(metric, band["default"])
            if not (value * lo <= row[metric] <= value * hi):
                errors.append(
                    f"{key}: {metric}={row[metric]:.4g} outside "
                    f"[{value * lo:.4g}, {value * hi:.4g}] "
                    f"(= ref {value:.4g} x [{lo}, {hi}])")

    # relative gate: the chunked ragged ref row must not fall back to the
    # pre-fused-gather regime (it used to run ~7x slower than packed —
    # attend-before-append plus the fused kernel body closed most of it)
    for gate in bands.get("ratio_gates", []):
        where = f"{bands_path} ratio_gates entry"
        gkey = _require(gate, "row", where)
        gvs = _require(gate, "vs", where)
        gmin = _require(gate, "min_ratio", where)
        num, den = rows.get(gkey), rows.get(gvs)
        if num is None or den is None:
            errors.append(f"ratio gate {gkey} vs {gvs}: row missing")
            continue
        if "tokens_per_s" not in num or "tokens_per_s" not in den:
            errors.append(f"ratio gate {gkey} vs {gvs}: a row lacks "
                          "tokens_per_s")
            continue
        ratio = num["tokens_per_s"] / den["tokens_per_s"]
        if ratio < gmin:
            errors.append(
                f"{gkey}: tokens_per_s is {ratio:.3f}x of "
                f"{gvs} (gate: >= {gmin}x) — "
                f"{gate.get('why', '')}")

    # fused dispatch gate: the decode_window row must actually be
    # dispatching windows — dispatch count bounded by per_window jit
    # calls per fused window plus a constant absorbing the per-request
    # admission (prefill + pack + first-token), select-boundary, and
    # sampling dispatches. A regression to per-step dispatch blows
    # straight through the bound.
    for gate in bands.get("dispatch_gates", []):
        where = f"{bands_path} dispatch_gates entry"
        gkey = _require(gate, "row", where)
        per_window = _require(gate, "per_window", where)
        const = _require(gate, "const", where)
        row = rows.get(gkey)
        if row is None:
            errors.append(f"dispatch gate {gkey}: row missing from "
                          f"{bench_path}")
            continue
        missing = [f for f in ("dispatches", "decode_steps",
                               "decode_window") if f not in row]
        if missing:
            errors.append(f"dispatch gate {gkey}: row lacks {missing} "
                          "(the --decode-window benchmark emits all)")
            continue
        windows = math.ceil(row["decode_steps"]
                            / max(row["decode_window"], 1))
        allowed = per_window * windows + const
        if row["dispatches"] > allowed:
            errors.append(
                f"{gkey}: dispatches={row['dispatches']} > {allowed} "
                f"(= {per_window} x ceil({row['decode_steps']}/"
                f"{row['decode_window']}) + {const}) — "
                f"{gate.get('why', '')}")

    # rebalance gate: a row serving the churn workload with
    # Engine(rebalance=...) must report its mean device-compute
    # imbalance REDUCED vs the same run's pre-check value
    # (benchmarks/serve_throughput.py --rebalance emits the pair)
    for gate in bands.get("imbalance_gates", []):
        where = f"{bands_path} imbalance_gates entry"
        gkey = _require(gate, "row", where)
        row = rows.get(gkey)
        if row is None:
            errors.append(f"imbalance gate {gkey}: row missing from "
                          f"{bench_path}")
            continue
        missing = [f for f in ("load_imbalance_pre", "load_imbalance_post")
                   if f not in row]
        if missing:
            errors.append(f"imbalance gate {gkey}: row lacks {missing} "
                          "(the --rebalance benchmark emits both)")
            continue
        pre, post = row["load_imbalance_pre"], row["load_imbalance_post"]
        strict = bool(gate.get("strict", False))
        if (post >= pre) if strict else (post > pre):
            errors.append(
                f"{gkey}: load_imbalance_post={post:.4f} not "
                f"{'<' if strict else '<='} pre={pre:.4f} — "
                f"{gate.get('why', '')}")
    return errors


def update(bench_path=BENCH, bands_path=BANDS):
    """Refresh the reference values in-place, preserving the band
    factors and ratio gates (hand-maintained policy)."""
    with open(bench_path) as f:
        bench = json.load(f)
    with open(bands_path) as f:
        bands = json.load(f)
    for key in bands["rows"]:
        row = next((r for r in bench["rows"] if row_key(r) == key), None)
        if row is None:
            raise SystemExit(f"--update: banded row {key} missing from "
                             f"{bench_path}")
        bands["rows"][key] = {m: row[m] for m in BANDED if m in row}
    with open(bands_path, "w") as f:
        json.dump(bands, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"check_bench: refreshed {len(bands['rows'])} reference rows "
          f"in {bands_path}")


def validate_trend_row(entry, where):
    """Hold one trend row against TREND_SCHEMA with named errors (a
    stale or hand-mangled bench_trend.jsonl line fails at append time,
    naming the line — not when a later reader chokes)."""
    if not isinstance(entry, dict):
        _schema_fail(f"{where}: trend row must be a JSON object, got "
                     f"{type(entry).__name__}")
    for key, typ in TREND_SCHEMA.items():
        if key not in entry:
            _schema_fail(f"{where}: trend row is missing required key "
                         f"{key!r} (schema keys: "
                         f"{sorted(TREND_SCHEMA)}); regenerate the row "
                         "or migrate the file")
        if not isinstance(entry[key], typ):
            _schema_fail(f"{where}: trend key {key!r} must be "
                         f"{typ.__name__}, got "
                         f"{type(entry[key]).__name__}")
    for k, v in entry["tokens_per_s"].items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            _schema_fail(f"{where}: tokens_per_s[{k!r}] must be a "
                         f"number, got {type(v).__name__}")


def append_trend(trend_path, bench_path=BENCH):
    """Append one JSONL trend row for the current commit: every bench
    row's tokens_per_s plus the tiered-residency, speculative,
    fused-window dispatch, and rebalance counters. Re-running on the same commit replaces that
    commit's row, so each PR contributes exactly one line to the
    trajectory file. Every row — existing and new — is validated
    against TREND_SCHEMA."""
    import subprocess

    with open(bench_path) as f:
        bench = json.load(f)
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO,
            capture_output=True, text=True, check=True).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        commit = "unknown"
    entry = {
        "commit": commit,
        "devices": bench.get("devices"),
        "tokens_per_s": {row_key(r): round(r["tokens_per_s"], 3)
                         for r in bench["rows"] if "tokens_per_s" in r},
    }
    tiered = next((r for r in bench["rows"] if r.get("tier") == "tiered"),
                  None)
    if tiered is not None:
        entry["tier"] = {k: tiered[k] for k in (
            "hot_pages", "oversubscription", "tier_hit_rate",
            "tier_hits", "tier_misses", "tier_spills", "tier_fills",
            "tier_prefetch", "tokens_match_resident") if k in tiered}
    spec = next((r for r in bench["rows"]
                 if r.get("workload") == "ngram" and r.get("spec_tokens")),
                None)
    if spec is not None:
        entry["spec"] = {k: spec[k] for k in (
            "spec_tokens", "draft", "mean_accepted_len", "steps_per_s",
            "speedup_vs_nonspec", "tokens_match_nonspec") if k in spec}
    fused = next((r for r in bench["rows"]
                  if (r.get("decode_window") or 0) > 1), None)
    if fused is not None:
        entry["fused"] = {k: fused[k] for k in (
            "decode_window", "fused_windows", "fused_steps",
            "dispatches", "steps_per_dispatch",
            "tokens_match_unfused", "speedup_vs_perstep") if k in fused}
    rb = next((r for r in bench["rows"]
               if r.get("rebalance") not in (None, "off")), None)
    if rb is not None:
        entry["rebalance"] = {k: rb[k] for k in (
            "rebalance", "migrations", "rebalances",
            "load_imbalance_pre", "load_imbalance_post",
            "tokens_match_norebalance") if k in rb}
    validate_trend_row(entry, "new row")
    lines = []
    if os.path.exists(trend_path):
        with open(trend_path) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
    for i, ln in enumerate(lines):
        try:
            parsed = json.loads(ln)
        except json.JSONDecodeError as e:
            _schema_fail(f"{trend_path}:{i + 1}: not valid JSON ({e})")
        validate_trend_row(parsed, f"{trend_path}:{i + 1}")
    if lines and json.loads(lines[-1]).get("commit") == commit:
        lines = lines[:-1]            # refresh this commit's row
    lines.append(json.dumps(entry, sort_keys=True))
    with open(trend_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"check_bench: trend -> {trend_path} ({len(lines)} commits)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default=BENCH)
    ap.add_argument("--bands", default=BANDS)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the reference values in the bands file "
                         "from the current benchmark artifact")
    ap.add_argument("--append-trend", default=None, metavar="PATH",
                    help="after a passing check, append this commit's "
                         "tokens_per_s + tier counters as one JSONL row "
                         "(same commit replaces its row)")
    args = ap.parse_args(argv)
    if args.update:
        update(args.bench, args.bands)
        return 0
    errors = check(args.bench, args.bands)
    for e in errors:
        print(f"check_bench: FAIL {e}", file=sys.stderr)
    if errors:
        return 1
    with open(args.bands) as f:
        n = len(json.load(f)["rows"])
    print(f"check_bench: OK ({n} banded rows in-band, recompile and "
          f"token-match flags clean)")
    if args.append_trend:
        append_trend(args.append_trend, args.bench)
    return 0


if __name__ == "__main__":
    sys.exit(main())
