#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins. Run from the
# repo root. FAST=1 skips the slow (multi-device subprocess) tests.
#
# The pallas-interpret parity tests are tier-1 ON PURPOSE and must stay
# out of the `slow` marker, so CPU-only CI always exercises the Pallas
# kernel path (docs/kernels.md): the kernel-vs-oracle sweeps incl.
# paged_attention_partial / combine_partials in tests/test_kernels.py
# and the engine attn-impl parity test in tests/test_serving.py all run
# even under FAST=1. Only the 8-fake-device subprocess acceptance tests
# carry the slow marker.
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
if [[ "${FAST:-0}" == "1" ]]; then
  ARGS+=(-m "not slow")
fi

python scripts/check_docs.py

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest "${ARGS[@]}" "$@"
