#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins. Run from the
# repo root. FAST=1 skips the slow (multi-device subprocess) tests.
#
# The pallas-interpret parity tests are tier-1 ON PURPOSE and must stay
# out of the `slow` marker, so CPU-only CI always exercises the Pallas
# kernel path (docs/kernels.md): the kernel-vs-oracle sweeps incl.
# paged_attention_partial / combine_partials in tests/test_kernels.py
# and the engine attn-impl parity test in tests/test_serving.py all run
# even under FAST=1. Only the 8-fake-device subprocess acceptance tests
# carry the slow marker.
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
if [[ "${FAST:-0}" == "1" ]]; then
  ARGS+=(-m "not slow")
fi

python scripts/check_docs.py

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest "${ARGS[@]}" "$@"

if [[ "${FAST:-0}" != "1" ]]; then
  # serve-throughput smoke: machine-readable perf rows (tok/s per
  # layout x impl x admission mode, occupancy, recompile flags, the
  # ref-vs-pallas comparison rows, the poisson-arrival TTFT/ITL
  # latency rows with the packed-vs-chunked prefill comparison, the
  # tiered-residency row pair at 2x oversubscribed page capacity, and
  # the sampling + speculative-decode rows: stochastic non-spec,
  # greedy + sampled spec (tokens_match_nonspec exact via the coupled
  # rejection sampler), the ngram-friendly workload pair carrying
  # the spec >= non-spec tokens/s ratio gate, the churn-workload
  # rebalance pair: off vs retire-triggered live slot migration,
  # token-exact with a strict imbalance-reduction gate, and the fused
  # decode-window trio on a widened share window: lockstep baseline,
  # per-step engine row, and the Engine(decode_window=8) row whose
  # reuse steps run as ONE dispatched scan — tokens_match_unfused
  # exact, fused >= per-step tokens/s ratio gate, dispatch-count gate)
  # -> BENCH_serve.json, held against the committed bands
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python \
      benchmarks/serve_throughput.py --requests 6 --max-batch 2 \
      --gen-max 8 --reps 1 --layout default,interleave \
      --prefill-chunk 8 --arrival poisson --attn-impl pallas \
      --tiered-hot-pages 9 --spec-tokens 4 --sampling 0.8,0.9 \
      --rebalance --decode-window 8 --json BENCH_serve.json
  # perf gate: tokens/s and TTFT within the committed bands
  # (benchmarks/bench_bands.json), recompile flags and chunked/pallas/
  # tiered/speculative/rebalance token-match flags exact, chunked-vs-
  # packed, tiered-vs-resident and speculative-vs-nonspec throughput
  # ratio floors, the rebalance imbalance_post < imbalance_pre gate;
  # on success, append this commit's row to the cross-PR perf
  # trajectory
  python scripts/check_bench.py --append-trend benchmarks/bench_trend.jsonl
  # ragged serving smoke rows on 8 fake devices, one per sharded layout
  # registry entry (coplace_shmap = shard_map partial attention;
  # interleave = GSPMD within-page token striping), each in both
  # admission modes: prefill-then-pack and chunked slot-resident
  # prefill (--prefill-chunk streams prompt KV into the sharded cache)
  for LAYOUT in coplace_shmap interleave; do
    for CHUNK in 0 8; do
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
          repro.launch.serve --arch smollm-360m --reduced \
          --workload ragged --requests 4 --max-batch 2 \
          --prompt-buckets 16,24 --gen-min 2 --gen-max 6 \
          --layout "$LAYOUT" --admission balanced \
          --prefill-chunk "$CHUNK"
    done
  done
  # fused decode-window smoke (docs/serving.md §Fused decode windows):
  # up to 8 reuse steps between selection boundaries run as ONE
  # dispatched scan with in-scan sampling and device-side retirement.
  # Default layout packed, then the 8-fake-device shard_map
  # co-placement entry with chunked prefill riding the mixed fused jit
  # through the layout decode_window hook — the widened --share-window
  # gives the window room to fuse (the reduced config pins it to 2)
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
      repro.launch.serve --arch smollm-360m --reduced \
      --workload ragged --requests 6 --max-batch 2 \
      --prompt-buckets 16,24 --gen-min 8 --gen-max 20 \
      --share-window 8 --decode-window 8
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
      repro.launch.serve --arch smollm-360m --reduced \
      --workload ragged --requests 4 --max-batch 2 \
      --prompt-buckets 16,24 --gen-min 8 --gen-max 20 \
      --layout coplace_shmap --admission balanced --prefill-chunk 8 \
      --share-window 8 --decode-window 8
  # chunked prefill through the Pallas chunk kernels (interpret mode on
  # CPU: a correctness row, not a perf row — docs/kernels.md)
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
      repro.launch.serve --arch smollm-360m --reduced \
      --workload ragged --requests 4 --max-batch 2 \
      --prompt-buckets 16,24 --gen-min 2 --gen-max 6 \
      --layout coplace_shmap --admission balanced \
      --prefill-chunk 8 --attn-impl pallas
  # chunked prefill over recurrent mixers (mamba2): the per-slot scan
  # state resumes across chunk boundaries (docs/serving.md)
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
      repro.launch.serve --arch zamba2-2.7b --reduced \
      --workload ragged --requests 4 --max-batch 2 \
      --prompt-buckets 16,24 --gen-min 2 --gen-max 6 \
      --prefill-chunk 8
  # rebalance smoke on the 8-fake-device coplace_shmap layout: the churn
  # workload with retire-triggered live slot migration must produce
  # bit-identical per-uid tokens vs rebalance="off", actually migrate
  # (rebalance_banks=2 — the default would clamp to max_batch banks =
  # one slot per bank = permutation-only plans), and stay recompile-free
  # after warmup (docs/serving.md "Rebalancing")
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH="src:${PYTHONPATH:+$PYTHONPATH:}." python - <<'EOF'
import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serving import Engine, Request

cfg = reduced(get_arch("smollm-360m"))
params = M.init_params(cfg, jax.random.PRNGKey(0))

def churn(seed=0, n=12):
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        s = int(rng.choice([8, 16, 24]))
        g = int(rng.integers(3, 20))
        prompt = rng.integers(0, cfg.vocab_size, size=(s,)).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt, max_new=g))
    return reqs

kw = dict(max_batch=4, capacity=64, prompt_buckets=[8, 16, 24],
          layout="coplace_shmap", admission="balanced")
base = Engine(cfg, params, **kw).run(churn())
eng = Engine(cfg, params, rebalance="retire", rebalance_banks=2, **kw)
got = eng.run(churn())
match = (sorted(base) == sorted(got)
         and all(base[u].tokens == got[u].tokens for u in base))
mig = eng.stats.migrations
sizes0 = eng.jit_cache_sizes()
eng.reset_metrics()
eng.run(churn(seed=5))
stable = eng.jit_cache_sizes() == sizes0
print(f"ci,rebalance_smoke,tokens_match,{match},migrations,{mig},"
      f"recompiled_after_warmup,{not stable}")
assert match and stable and mig > 0
EOF
fi
