#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins. Run from the
# repo root. FAST=1 skips the slow (multi-device subprocess) tests.
#
# The pallas-interpret parity tests are tier-1 ON PURPOSE and must stay
# out of the `slow` marker, so CPU-only CI always exercises the Pallas
# kernel path (docs/kernels.md): the kernel-vs-oracle sweeps incl.
# paged_attention_partial / combine_partials in tests/test_kernels.py
# and the engine attn-impl parity test in tests/test_serving.py all run
# even under FAST=1. Only the 8-fake-device subprocess acceptance tests
# carry the slow marker.
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
if [[ "${FAST:-0}" == "1" ]]; then
  ARGS+=(-m "not slow")
fi

python scripts/check_docs.py

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest "${ARGS[@]}" "$@"

if [[ "${FAST:-0}" != "1" ]]; then
  # serve-throughput smoke: machine-readable perf rows (tok/s per
  # layout x impl x admission mode, occupancy, recompile flags, the
  # ref-vs-pallas comparison rows, the poisson-arrival TTFT/ITL
  # latency rows with the packed-vs-chunked prefill comparison, the
  # tiered-residency row pair at 2x oversubscribed page capacity, and
  # the sampling + speculative-decode rows: stochastic non-spec,
  # greedy + sampled spec (tokens_match_nonspec exact via the coupled
  # rejection sampler), and the ngram-friendly workload pair carrying
  # the spec >= non-spec tokens/s ratio gate)
  # -> BENCH_serve.json, held against the committed bands
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python \
      benchmarks/serve_throughput.py --requests 6 --max-batch 2 \
      --gen-max 8 --reps 1 --layout default,interleave \
      --prefill-chunk 8 --arrival poisson --attn-impl pallas \
      --tiered-hot-pages 9 --spec-tokens 4 --sampling 0.8,0.9 \
      --json BENCH_serve.json
  # perf gate: tokens/s and TTFT within the committed bands
  # (benchmarks/bench_bands.json), recompile flags and chunked/pallas/
  # tiered/speculative token-match flags exact, chunked-vs-packed,
  # tiered-vs-resident and speculative-vs-nonspec throughput ratio
  # floors; on success, append this commit's row to the cross-PR perf
  # trajectory
  python scripts/check_bench.py --append-trend benchmarks/bench_trend.jsonl
  # ragged serving smoke rows on 8 fake devices, one per sharded layout
  # registry entry (coplace_shmap = shard_map partial attention;
  # interleave = GSPMD within-page token striping), each in both
  # admission modes: prefill-then-pack and chunked slot-resident
  # prefill (--prefill-chunk streams prompt KV into the sharded cache)
  for LAYOUT in coplace_shmap interleave; do
    for CHUNK in 0 8; do
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
          repro.launch.serve --arch smollm-360m --reduced \
          --workload ragged --requests 4 --max-batch 2 \
          --prompt-buckets 16,24 --gen-min 2 --gen-max 6 \
          --layout "$LAYOUT" --admission balanced \
          --prefill-chunk "$CHUNK"
    done
  done
  # chunked prefill through the Pallas chunk kernels (interpret mode on
  # CPU: a correctness row, not a perf row — docs/kernels.md)
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
      repro.launch.serve --arch smollm-360m --reduced \
      --workload ragged --requests 4 --max-batch 2 \
      --prompt-buckets 16,24 --gen-min 2 --gen-max 6 \
      --layout coplace_shmap --admission balanced \
      --prefill-chunk 8 --attn-impl pallas
  # chunked prefill over recurrent mixers (mamba2): the per-slot scan
  # state resumes across chunk boundaries (docs/serving.md)
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m \
      repro.launch.serve --arch zamba2-2.7b --reduced \
      --workload ragged --requests 4 --max-batch 2 \
      --prompt-buckets 16,24 --gen-min 2 --gen-max 6 \
      --prefill-chunk 8
fi
