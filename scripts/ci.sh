#!/usr/bin/env bash
# Tier-1 verification: the exact command the roadmap pins. Run from the
# repo root. FAST=1 skips the slow (multi-device subprocess) tests.
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
if [[ "${FAST:-0}" == "1" ]]; then
  ARGS+=(-m "not slow")
fi

python scripts/check_docs.py

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest "${ARGS[@]}" "$@"
